//! Deterministic pseudo-random number generation.
//!
//! `xoshiro256**` (Blackman & Vigna) seeded through `SplitMix64`, the
//! canonical seeding recipe. Deterministic seeds make every experiment in
//! `EXPERIMENTS.md` exactly reproducible: the same `(dataset, seed)` pair
//! always yields the same trace, mapping, and schedule.

/// One SplitMix64 step: advance `state` by the golden-ratio increment and
/// return the finalized output. Full-avalanche; also used standalone as a
/// hash finalizer (e.g. the cluster hash ring).
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// A `xoshiro256**` PRNG. Not cryptographic; statistically strong and fast,
/// which is what a workload generator needs.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed via SplitMix64 expansion.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        // All-zero state is the one forbidden state; SplitMix64 cannot emit
        // four zeros from any seed, but guard anyway.
        let s = if s == [0; 4] { [1, 2, 3, 4] } else { s };
        Self { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform `f64` in `[0, 1)` with 53 bits of entropy.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` using Lemire's multiply-shift
    /// rejection method (unbiased).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0) is meaningless");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, bound)`.
    #[inline]
    pub fn index(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast here).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            if u1 > 1e-300 {
                let u2 = self.next_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Poisson(λ) via Knuth's method for small λ, normal approximation above.
    pub fn poisson(&mut self, lambda: f64) -> u64 {
        if lambda <= 0.0 {
            return 0;
        }
        if lambda < 30.0 {
            let l = (-lambda).exp();
            let mut k = 0u64;
            let mut p = 1.0;
            loop {
                p *= self.next_f64();
                if p <= l {
                    return k;
                }
                k += 1;
            }
        } else {
            let v = lambda + lambda.sqrt() * self.normal();
            v.max(0.0).round() as u64
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (Floyd's algorithm).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} distinct from {n}");
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.index(j + 1);
            let v = if chosen.contains(&t) { j } else { t };
            chosen.insert(v);
            out.push(v);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::new(9);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            counts[r.below(10) as usize] += 1;
        }
        for &c in &counts {
            // each bucket expects 10_000; allow ±5%
            assert!((9_500..=10_500).contains(&c), "bucket count {c}");
        }
    }

    #[test]
    fn range_inclusive_bounds_hit() {
        let mut r = Rng::new(3);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..10_000 {
            match r.range(5, 8) {
                5 => lo_seen = true,
                8 => hi_seen = true,
                6 | 7 => {}
                other => panic!("out of range: {other}"),
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn poisson_mean_matches_lambda() {
        let mut r = Rng::new(13);
        for &lambda in &[0.5, 5.0, 50.0] {
            let n = 50_000;
            let mean = (0..n).map(|_| r.poisson(lambda) as f64).sum::<f64>() / n as f64;
            assert!(
                (mean - lambda).abs() < lambda.max(1.0) * 0.05,
                "lambda {lambda} mean {mean}"
            );
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_distinct_has_no_duplicates() {
        let mut r = Rng::new(19);
        for _ in 0..100 {
            let s = r.sample_distinct(50, 20);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), 20);
            assert!(s.iter().all(|&x| x < 50));
        }
    }
}
