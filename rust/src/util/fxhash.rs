//! A fast non-cryptographic hasher (FxHash-style multiplicative mixing).
//!
//! The co-occurrence graph build performs tens of millions of hash-map
//! operations on `u64` pair keys; std's SipHash is DoS-resistant but ~4x
//! slower than needed for keys we generate ourselves. This is the
//! rustc-internal FxHash recipe (word-at-a-time multiply-xor), which is
//! the standard choice for trusted integer keys.

use std::hash::{BuildHasherDefault, Hasher};

/// Multiplicative word hasher.
#[derive(Debug, Clone, Copy, Default)]
pub struct FxHasher {
    hash: u64,
}

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, word: u64) {
        self.hash = (self.hash.rotate_left(5) ^ word).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add_to_hash(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rem.len()].copy_from_slice(rem);
            self.add_to_hash(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.add_to_hash(n as u64);
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.add_to_hash(n);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.add_to_hash(n as u64);
    }
}

/// `BuildHasher` for `HashMap`/`HashSet` with trusted keys.
pub type FxBuild = BuildHasherDefault<FxHasher>;

/// A `HashMap` keyed with [`FxHasher`].
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, FxBuild>;

/// A `HashSet` keyed with [`FxHasher`].
pub type FxHashSet<T> = std::collections::HashSet<T, FxBuild>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_works() {
        let mut m: FxHashMap<u64, u32> = FxHashMap::default();
        for i in 0..10_000u64 {
            *m.entry(i % 257).or_insert(0) += 1;
        }
        assert_eq!(m.len(), 257);
        assert_eq!(m.values().sum::<u32>(), 10_000);
    }

    #[test]
    fn distinct_keys_distinct_hashes_mostly() {
        // Not a collision test per se; just sanity that the hash spreads.
        use std::hash::{BuildHasher, Hash};
        let b = FxBuild::default();
        let mut seen = std::collections::HashSet::new();
        for i in 0..10_000u64 {
            let mut h = b.build_hasher();
            i.hash(&mut h);
            seen.insert(h.finish());
        }
        assert_eq!(seen.len(), 10_000);
    }

    #[test]
    fn byte_writes_cover_remainder_path() {
        let mut h = FxHasher::default();
        h.write(&[1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11]); // 8 + 3 remainder
        let a = h.finish();
        let mut h2 = FxHasher::default();
        h2.write(&[1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 12]);
        assert_ne!(a, h2.finish());
    }
}
