//! A minimal declarative command-line parser (offline stand-in for `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments; generates usage text from the declared options. Only what the
//! `recross` launcher and the examples need.

use std::collections::{HashMap, HashSet};

/// Declared option kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    Flag,
    Value,
}

/// One declared option.
#[derive(Debug, Clone)]
struct Opt {
    name: &'static str,
    kind: Kind,
    default: Option<String>,
    help: &'static str,
}

/// A tiny argument parser: declare options, then [`Args::parse`].
#[derive(Debug, Default)]
pub struct ArgSpec {
    opts: Vec<Opt>,
    positional: Vec<(&'static str, &'static str)>,
    about: &'static str,
}

/// Parsed arguments.
#[derive(Debug)]
pub struct Args {
    values: HashMap<&'static str, String>,
    flags: HashMap<&'static str, bool>,
    positional: Vec<String>,
    /// Options the user passed explicitly (as opposed to declared
    /// defaults) — the signal [`crate::config::Config::overlay_cli`] uses
    /// to decide whether a CLI value outranks a TOML one.
    provided: HashSet<&'static str>,
}

impl ArgSpec {
    /// New spec with a one-line description (shown in `--help`).
    pub fn new(about: &'static str) -> Self {
        Self {
            about,
            ..Default::default()
        }
    }

    /// Declare a boolean `--name` flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(Opt {
            name,
            kind: Kind::Flag,
            default: None,
            help,
        });
        self
    }

    /// Declare a `--name <value>` option with a default.
    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.opts.push(Opt {
            name,
            kind: Kind::Value,
            default: Some(default.to_string()),
            help,
        });
        self
    }

    /// Declare a required positional argument.
    pub fn positional(mut self, name: &'static str, help: &'static str) -> Self {
        self.positional.push((name, help));
        self
    }

    /// Usage text.
    pub fn usage(&self, prog: &str) -> String {
        let mut s = format!("{}\n\nUSAGE: {prog}", self.about);
        for (p, _) in &self.positional {
            s.push_str(&format!(" <{p}>"));
        }
        s.push_str(" [OPTIONS]\n\nOPTIONS:\n");
        for o in &self.opts {
            let lhs = match o.kind {
                Kind::Flag => format!("  --{}", o.name),
                Kind::Value => format!("  --{} <v>", o.name),
            };
            let def = o
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("{lhs:<26} {}{def}\n", o.help));
        }
        for (p, h) in &self.positional {
            s.push_str(&format!("  <{p:<22}> {h}\n"));
        }
        s
    }

    /// Parse an argv slice (without the program name). Returns `Err` with a
    /// usage-style message on malformed input or `--help`.
    pub fn parse(&self, argv: &[String]) -> Result<Args, String> {
        let mut values = HashMap::new();
        let mut flags = HashMap::new();
        let mut provided = HashSet::new();
        for o in &self.opts {
            match o.kind {
                Kind::Flag => {
                    flags.insert(o.name, false);
                }
                Kind::Value => {
                    values.insert(o.name, o.default.clone().unwrap_or_default());
                }
            }
        }
        let mut positional = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(self.usage("recross"));
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (stripped, None),
                };
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.usage("recross")))?;
                match opt.kind {
                    Kind::Flag => {
                        if inline_val.is_some() {
                            return Err(format!("--{key} takes no value"));
                        }
                        flags.insert(opt.name, true);
                        provided.insert(opt.name);
                    }
                    Kind::Value => {
                        let v = match inline_val {
                            Some(v) => v,
                            None => {
                                i += 1;
                                argv.get(i)
                                    .cloned()
                                    .ok_or_else(|| format!("--{key} requires a value"))?
                            }
                        };
                        values.insert(opt.name, v);
                        provided.insert(opt.name);
                    }
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        if positional.len() < self.positional.len() {
            return Err(format!(
                "missing positional argument <{}>\n\n{}",
                self.positional[positional.len()].0,
                self.usage("recross")
            ));
        }
        Ok(Args {
            values,
            flags,
            positional,
            provided,
        })
    }
}

impl Args {
    /// Get a value option as a string.
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("option --{name} was not declared"))
    }

    /// Get a value option parsed to any `FromStr` type.
    pub fn get_as<T: std::str::FromStr>(&self, name: &str) -> Result<T, String>
    where
        T::Err: std::fmt::Display,
    {
        self.get(name)
            .parse()
            .map_err(|e| format!("--{name}: {e}"))
    }

    /// Get a value option parsed as a strictly positive integer (count
    /// knobs like `--shards` or `--batch`, where 0 is always a user error).
    pub fn get_positive(&self, name: &str) -> Result<usize, String> {
        let v: usize = self.get_as(name)?;
        if v == 0 {
            return Err(format!("--{name} must be at least 1"));
        }
        Ok(v)
    }

    /// Was this option (value or flag) passed explicitly on the command
    /// line? `false` for declared defaults and for undeclared names, so
    /// callers can probe without knowing which subcommand's spec is live.
    pub fn provided(&self, name: &str) -> bool {
        self.provided.contains(name)
    }

    /// Was a flag present?
    pub fn flag(&self, name: &str) -> bool {
        *self
            .flags
            .get(name)
            .unwrap_or_else(|| panic!("flag --{name} was not declared"))
    }

    /// Positional argument by index.
    pub fn pos(&self, idx: usize) -> Option<&str> {
        self.positional.get(idx).map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> ArgSpec {
        ArgSpec::new("test")
            .flag("verbose", "be loud")
            .opt("seed", "42", "rng seed")
            .opt("dataset", "software", "dataset name")
            .positional("cmd", "subcommand")
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = spec().parse(&sv(&["run"])).unwrap();
        assert_eq!(a.get("seed"), "42");
        assert!(!a.flag("verbose"));
        assert_eq!(a.pos(0), Some("run"));
    }

    #[test]
    fn key_value_both_styles() {
        let a = spec()
            .parse(&sv(&["run", "--seed", "7", "--dataset=sports"]))
            .unwrap();
        assert_eq!(a.get_as::<u64>("seed").unwrap(), 7);
        assert_eq!(a.get("dataset"), "sports");
    }

    #[test]
    fn flags_toggle() {
        let a = spec().parse(&sv(&["run", "--verbose"])).unwrap();
        assert!(a.flag("verbose"));
    }

    #[test]
    fn provided_tracks_explicit_options_only() {
        let a = spec()
            .parse(&sv(&["run", "--seed", "7", "--verbose"]))
            .unwrap();
        assert!(a.provided("seed"));
        assert!(a.provided("verbose"));
        assert!(!a.provided("dataset"), "defaults are not 'provided'");
        assert!(!a.provided("no-such-option"), "undeclared names are safe");
        let b = spec().parse(&sv(&["run", "--dataset=sports"])).unwrap();
        assert!(b.provided("dataset"));
        assert!(!b.provided("seed"));
    }

    #[test]
    fn get_positive_rejects_zero() {
        let a = spec().parse(&sv(&["run", "--seed", "0"])).unwrap();
        assert!(a.get_positive("seed").is_err());
        let a = spec().parse(&sv(&["run", "--seed", "3"])).unwrap();
        assert_eq!(a.get_positive("seed").unwrap(), 3);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(spec().parse(&sv(&["run", "--nope"])).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(spec().parse(&sv(&["run", "--seed"])).is_err());
    }

    #[test]
    fn missing_positional_rejected() {
        assert!(spec().parse(&sv(&[])).is_err());
    }

    #[test]
    fn help_returns_usage() {
        let err = spec().parse(&sv(&["--help"])).unwrap_err();
        assert!(err.contains("USAGE"));
        assert!(err.contains("--seed"));
    }
}
