//! A criterion-style measurement harness (offline stand-in for `criterion`).
//!
//! `cargo bench` targets are declared with `harness = false` and call into
//! this module: warm-up, timed iterations, mean/median/stddev, and a
//! one-line report per benchmark. Results can also be appended to a TSV so
//! `EXPERIMENTS.md` numbers are regenerable.

use std::time::{Duration, Instant};

/// Measurement settings.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    /// Wall-clock spent warming up before measuring.
    pub warmup: Duration,
    /// Target wall-clock for the measurement phase.
    pub measure: Duration,
    /// Hard cap on measured iterations (keeps slow end-to-end benches sane).
    pub max_iters: u64,
    /// Minimum measured iterations regardless of duration.
    pub min_iters: u64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(300),
            measure: Duration::from_secs(2),
            max_iters: 10_000,
            min_iters: 5,
        }
    }
}

/// Summary statistics for one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub stddev_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl BenchResult {
    /// One-line human report, criterion-flavored.
    pub fn report(&self) -> String {
        format!(
            "{:<44} time: [{} {} {}]  (σ {}, {} iters)",
            self.name,
            super::fmt_ns(self.min_ns),
            super::fmt_ns(self.median_ns),
            super::fmt_ns(self.max_ns),
            super::fmt_ns(self.stddev_ns),
            self.iters
        )
    }
}

/// A benchmark group that prints results as they complete.
pub struct Bench {
    cfg: BenchConfig,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        Self::with_config(BenchConfig::default())
    }

    pub fn with_config(cfg: BenchConfig) -> Self {
        Self {
            cfg,
            results: Vec::new(),
        }
    }

    /// Run one benchmark: `f` is called once per iteration; its return value
    /// is black-boxed to keep the optimizer honest.
    pub fn run<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warm-up.
        let wstart = Instant::now();
        while wstart.elapsed() < self.cfg.warmup {
            black_box(f());
        }
        // Measure.
        let mut samples = Vec::new();
        let mstart = Instant::now();
        while (mstart.elapsed() < self.cfg.measure && (samples.len() as u64) < self.cfg.max_iters)
            || (samples.len() as u64) < self.cfg.min_iters
        {
            let t = Instant::now();
            black_box(f());
            samples.push(t.elapsed().as_nanos() as f64);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len() as f64;
        let mean = samples.iter().sum::<f64>() / n;
        let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n;
        let res = BenchResult {
            name: name.to_string(),
            iters: samples.len() as u64,
            mean_ns: mean,
            median_ns: samples[samples.len() / 2],
            stddev_ns: var.sqrt(),
            min_ns: samples[0],
            max_ns: *samples.last().unwrap(),
        };
        println!("{}", res.report());
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// All results so far.
    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Append results as TSV rows (`name\tmean_ns\tmedian_ns\tstddev_ns`).
    pub fn write_tsv(&self, path: &str) -> std::io::Result<()> {
        use std::io::Write;
        let mut f = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)?;
        for r in &self.results {
            writeln!(
                f,
                "{}\t{:.1}\t{:.1}\t{:.1}",
                r.name, r.mean_ns, r.median_ns, r.stddev_ns
            )?;
        }
        Ok(())
    }
}

/// Optimizer barrier (stable-rust version of `std::hint::black_box`; kept
/// behind a function so benches don't depend on unstable features).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_cfg() -> BenchConfig {
        BenchConfig {
            warmup: Duration::from_millis(1),
            measure: Duration::from_millis(10),
            max_iters: 1000,
            min_iters: 3,
        }
    }

    #[test]
    fn runs_and_reports() {
        let mut b = Bench::with_config(fast_cfg());
        let r = b.run("noop", || 1 + 1).clone();
        assert!(r.iters >= 3);
        assert!(r.mean_ns >= 0.0);
        assert!(r.min_ns <= r.median_ns && r.median_ns <= r.max_ns);
    }

    #[test]
    fn tsv_written() {
        let path = std::env::temp_dir().join("recross_bench_test.tsv");
        let path = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);
        let mut b = Bench::with_config(fast_cfg());
        b.run("a", || 0);
        b.run("b", || 0);
        b.write_tsv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        let _ = std::fs::remove_file(&path);
    }
}
