//! Hot-path microbenchmarks — the targets of the §Perf optimization pass
//! (EXPERIMENTS.md §Perf records before/after for each).
//!
//! * offline: co-occurrence graph build, Algorithm 1 grouping
//! * online:  per-batch scheduling (the simulator's inner loop),
//!            activation-set computation, replica selection
//! * serving: planner pass construction, tile gathering, and (when
//!            artifacts exist) a real PJRT reduce invocation
//!
//! `--smoke` shrinks the workload and the per-section budgets to a
//! seconds-scale run — the CI smoke step builds and drives every bench
//! the same way.

use recross::config::Config;
use recross::coordinator::{EmbeddingStore, Planner};
use recross::engine::{Engine, Scheme};
use recross::graph::CoGraph;
use recross::sched::Scratch;
use recross::util::bench::{black_box, Bench, BenchConfig};
use recross::util::Rng;
use recross::workload::{generate, DatasetSpec, Query};
use std::time::Duration;

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (scale, n_history, n_eval) = if smoke { (0.05, 800, 512) } else { (0.2, 4_000, 512) };
    let spec = DatasetSpec::by_name("software").unwrap().scaled(scale);
    let (history, eval) = generate(&spec, n_history, n_eval, 42);
    let cfg = Config::paper_default();

    let mut bench = Bench::with_config(if smoke {
        BenchConfig {
            warmup: Duration::from_millis(20),
            measure: Duration::from_millis(100),
            max_iters: 1_000,
            min_iters: 2,
        }
    } else {
        BenchConfig {
            warmup: Duration::from_millis(200),
            measure: Duration::from_secs(1),
            max_iters: 10_000,
            min_iters: 3,
        }
    });

    // --- offline phase -----------------------------------------------------
    bench.run("offline/cograph(history)", || {
        black_box(CoGraph::build(&history))
    });
    let graph = CoGraph::build(&history);
    bench.run("offline/alg1(full prepare)", || {
        black_box(Engine::prepare(Scheme::ReCross, &graph, &history, &cfg))
    });

    // --- online phase ------------------------------------------------------
    let engine = Engine::prepare(Scheme::ReCross, &graph, &history, &cfg);
    let mut scratch = Scratch::default();
    let batch: Vec<Query> = eval.queries[..256.min(eval.queries.len())].to_vec();
    bench.run("online/run_batch(256 queries)", || {
        black_box(engine.run_batch(&batch, &mut scratch))
    });
    bench.run("online/count_activations(512q)", || {
        black_box(engine.count_activations(&eval))
    });
    let mut gscratch = Vec::new();
    bench.run("online/groups_touched(1 query)", || {
        black_box(
            engine
                .mapping()
                .groups_touched(&eval.queries[0].items, &mut gscratch),
        )
    });

    // --- serving path --------------------------------------------------------
    let store = EmbeddingStore::random(engine.mapping(), 16, 64, 1);
    let planner = Planner::new(engine.mapping(), &store, 8);
    let q = &eval.queries[0];
    bench.run("serve/plan(1 query)", || black_box(planner.plan(q)));
    let passes = planner.plan(q);
    let mut tiles = Vec::new();
    bench.run("serve/gather_tiles(1 pass)", || {
        planner.gather_tiles(&passes[0], &mut tiles);
        black_box(tiles.len())
    });

    // --- PJRT reduce (needs artifacts) ---------------------------------------
    if recross::runtime::artifacts_available("artifacts") {
        let rt = recross::runtime::Runtime::load("artifacts").expect("runtime");
        let m = rt.manifest().clone();
        let mut rng = Rng::new(3);
        let masks: Vec<f32> = (0..m.tiles * m.xbar_rows)
            .map(|_| if rng.chance(0.1) { 1.0 } else { 0.0 })
            .collect();
        let tiles_buf: Vec<f32> = (0..m.tiles * m.xbar_rows * m.embed_dim)
            .map(|_| rng.normal() as f32 * 0.1)
            .collect();
        bench.run("pjrt/reduce_b1", || {
            black_box(rt.reduce(1, &masks, &tiles_buf).unwrap())
        });
    } else {
        println!("(skipping pjrt/reduce_b1 — run `make artifacts`)");
    }

    let _ = bench.write_tsv("target/bench_hotpath.tsv");
}
