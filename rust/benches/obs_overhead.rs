//! Observability overhead: what does the metrics/trace plane cost?
//!
//! The obs contract (DESIGN.md §Observability) has two clauses this
//! bench pins:
//!
//! 1. **Disabled is free.** A backend with no [`recross::obs::Obs`]
//!    handle attached, and one with a *disabled* handle attached, must
//!    drive at the same speed — every record call is a single branch.
//! 2. **Enabled never perturbs.** Recording harvests values the serving
//!    path already computed, so the drive's output is bit-identical
//!    with recording on or off (asserted here before any measurement).
//!
//! Runs the open-loop driver over a synthetic Zipf workload on the
//! single-executor and 4-shard simulators, four ways each — no handle,
//! disabled handle, enabled handle (full sampling), and enabled handle
//! plus a per-drive telemetry tick (snapshot diff + SLO evaluation) —
//! and writes **`BENCH_obs.json`** at the repository root. CI runs
//! `--smoke` on every push and uploads the file as an artifact. The
//! `disabled/none` ratio is asserted `< 1.25` in full mode only (smoke
//! budgets are too short to bound noise).

use recross::allocation::Replication;
use recross::cluster::{PoolShared, ShardPlan};
use recross::config::{HardwareConfig, ObsConfig, SloConfig, WatchConfig};
use recross::coordinator::BatchPolicy;
use recross::deploy::{Backend, SimBackend};
use recross::grouping::Mapping;
use recross::loadgen::{drive, Arrivals};
use recross::obs::{Obs, Watcher};
use recross::util::bench::black_box;
use recross::util::{Clock, Rng, SimClock, Zipf};
use recross::workload::Query;
use recross::xbar::{CircuitParams, CrossbarModel};
use std::time::{Duration, Instant};

const GROUP_SIZE: usize = 32;

struct Fixture {
    shared: PoolShared,
    queries: Vec<Query>,
    arrivals: Vec<u64>,
    policy: BatchPolicy,
}

fn fixture(groups: usize, n_queries: usize, pooling: usize, seed: u64) -> Fixture {
    let n = groups * GROUP_SIZE;
    let group_lists: Vec<Vec<u32>> = (0..groups)
        .map(|g| ((g * GROUP_SIZE) as u32..((g + 1) * GROUP_SIZE) as u32).collect())
        .collect();
    let mapping = Mapping::from_groups(group_lists, GROUP_SIZE, n);
    let batch = 32usize;
    let mut rng = Rng::new(seed);
    let zipf = Zipf::new(n, 1.05);
    let queries: Vec<Query> = (0..n_queries)
        .map(|_| Query::new((0..pooling).map(|_| zipf.sample(&mut rng) as u32).collect()))
        .collect();
    // ~2M qps offered: batches form under pressure, so the batcher and
    // span record paths are exercised on nearly every close.
    let arrivals = Arrivals::poisson(2_000_000.0, seed ^ 0xA11).take(n_queries);
    Fixture {
        shared: PoolShared {
            replication: Replication::from_copies(vec![2; groups], batch),
            mapping,
            model: CrossbarModel::new(&HardwareConfig::default(), &CircuitParams::default()),
            dynamic_switch: true,
        },
        queries,
        arrivals,
        policy: BatchPolicy {
            max_batch: batch,
            max_wait: Duration::from_micros(50),
        },
    }
}

/// Mean wall-clock ns per call of `f`, with warm-up.
fn measure<F: FnMut()>(mut f: F, measure_ns: u64, min_iters: u64) -> f64 {
    let warm = Instant::now();
    let warm_budget = Duration::from_nanos(measure_ns / 4);
    let mut warm_iters = 0u64;
    while warm.elapsed() < warm_budget || warm_iters < 2 {
        f();
        warm_iters += 1;
    }
    let start = Instant::now();
    let budget = Duration::from_nanos(measure_ns);
    let mut iters = 0u64;
    while start.elapsed() < budget || iters < min_iters {
        f();
        iters += 1;
    }
    start.elapsed().as_nanos() as f64 / iters as f64
}

struct Row {
    name: &'static str,
    shards: usize,
    queries: usize,
    none_ns: f64,
    disabled_ns: f64,
    enabled_ns: f64,
    /// Enabled handle + one telemetry tick (snapshot, window diff, SLO
    /// evaluation) per drive — the watch loop's steady-state cost.
    ticked_ns: f64,
}

fn run_point(name: &'static str, fx: &Fixture, shards: usize, measure_ns: u64) -> Row {
    let make = || {
        let b = SimBackend::single(&fx.shared);
        if shards > 1 {
            // Round-robin group ownership: every shard hot, every query
            // fanning out — the worst case for the scatter/merge records.
            let assign: Vec<u32> = (0..fx.shared.mapping.num_groups())
                .map(|g| (g % shards) as u32)
                .collect();
            b.into_sharded(ShardPlan::from_assignment(assign, shards))
        } else {
            b
        }
    };
    let enabled_obs = Obs::from_config(&ObsConfig {
        enabled: true,
        sample_rate: 1.0,
        ring_capacity: 4096,
    });

    let none = make();
    let disabled = make().with_obs(Obs::disabled());
    let enabled = make().with_obs(enabled_obs);

    // Correctness gate: recording must not perturb the drive. A fast
    // observability plane that changes the answer is worthless.
    let base = drive(&none, &fx.queries, &fx.arrivals, &fx.policy);
    let under_disabled = drive(&disabled, &fx.queries, &fx.arrivals, &fx.policy);
    let under_enabled = drive(&enabled, &fx.queries, &fx.arrivals, &fx.policy);
    assert_eq!(base, under_disabled, "{name}: disabled obs perturbed the drive");
    assert_eq!(base, under_enabled, "{name}: enabled obs perturbed the drive");
    // ...and neither must a telemetry tick between drives: snapshots
    // are read-only on the serving path.
    let mut watcher = Watcher::from_config(&WatchConfig::default(), &SloConfig::default());
    let clock = SimClock::new();
    clock.advance(1_000_000);
    black_box(watcher.tick(clock.now_ns(), &enabled.metrics().expect("snapshot")));
    let after_tick = drive(&enabled, &fx.queries, &fx.arrivals, &fx.policy);
    assert_eq!(base, after_tick, "{name}: watcher tick perturbed a subsequent drive");

    let time = |b: &SimBackend| {
        measure(
            || {
                black_box(drive(b, &fx.queries, &fx.arrivals, &fx.policy));
            },
            measure_ns,
            3,
        )
    };
    let none_ns = time(&none);
    let disabled_ns = time(&disabled);
    let enabled_ns = time(&enabled);
    let ticked_ns = measure(
        || {
            black_box(drive(&enabled, &fx.queries, &fx.arrivals, &fx.policy));
            clock.advance(1_000_000);
            let snap = enabled.metrics().expect("snapshot");
            black_box(watcher.tick(clock.now_ns(), &snap));
        },
        measure_ns,
        3,
    );
    Row {
        name,
        shards,
        queries: fx.queries.len(),
        none_ns,
        disabled_ns,
        enabled_ns,
        ticked_ns,
    }
}

fn json(rows: &[Row], smoke: bool) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"bench\": \"obs_overhead\",\n");
    out.push_str("  \"version\": 2,\n");
    out.push_str(&format!("  \"mode\": \"{}\",\n", if smoke { "smoke" } else { "full" }));
    out.push_str("  \"configs\": [\n");
    for (i, r) in rows.iter().enumerate() {
        out.push_str("    {\n");
        out.push_str(&format!(
            "      \"name\": \"{}\", \"shards\": {}, \"queries\": {},\n",
            r.name, r.shards, r.queries
        ));
        out.push_str(&format!(
            "      \"none_ns_per_drive\": {:.1}, \"disabled_ns_per_drive\": {:.1}, \
             \"enabled_ns_per_drive\": {:.1}, \"ticked_ns_per_drive\": {:.1},\n",
            r.none_ns, r.disabled_ns, r.enabled_ns, r.ticked_ns
        ));
        out.push_str(&format!(
            "      \"disabled_over_none\": {:.4}, \"enabled_over_none\": {:.4}, \
             \"ticked_over_none\": {:.4}\n",
            r.disabled_ns / r.none_ns,
            r.enabled_ns / r.none_ns,
            r.ticked_ns / r.none_ns
        ));
        out.push_str(if i + 1 == rows.len() { "    }\n" } else { "    },\n" });
    }
    out.push_str("  ]\n}\n");
    out
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (fx, measure_ns) = if smoke {
        (fixture(32, 128, 8, 0x0B5), 50_000_000u64) // 50 ms/variant: seconds total
    } else {
        (fixture(128, 512, 16, 0x0B5), 1_000_000_000u64)
    };

    println!(
        "== observability overhead: none vs disabled vs enabled vs ticked handle, {} mode ==\n",
        if smoke { "smoke" } else { "full" }
    );
    println!(
        "{:<10} {:>6} {:>8} {:>13} {:>13} {:>13} {:>13} {:>9} {:>9} {:>9}",
        "config", "shards", "queries", "none ns", "disabled ns", "enabled ns", "ticked ns",
        "dis/none", "en/none", "tick/none"
    );

    let mut rows = Vec::new();
    for (name, shards) in [("single", 1usize), ("sharded4", 4)] {
        let row = run_point(name, &fx, shards, measure_ns);
        println!(
            "{:<10} {:>6} {:>8} {:>13.0} {:>13.0} {:>13.0} {:>13.0} {:>8.3}x {:>8.3}x {:>8.3}x",
            row.name,
            row.shards,
            row.queries,
            row.none_ns,
            row.disabled_ns,
            row.enabled_ns,
            row.ticked_ns,
            row.disabled_ns / row.none_ns,
            row.enabled_ns / row.none_ns,
            row.ticked_ns / row.none_ns,
        );
        rows.push(row);
    }

    if !smoke {
        // Clause 1 of the contract: a disabled handle costs ~nothing.
        // 1.25 is a generous noise bound for a second-scale measurement;
        // a real regression (a lock or allocation on the disabled path)
        // shows up as an integer multiple, not a quarter.
        for r in &rows {
            let ratio = r.disabled_ns / r.none_ns;
            assert!(
                ratio < 1.25,
                "{}: disabled obs handle costs {:.1}% over no handle",
                r.name,
                (ratio - 1.0) * 100.0
            );
        }
    }

    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_obs.json");
    std::fs::write(&path, json(&rows, smoke)).expect("writing BENCH_obs.json");
    println!("\nwrote {}", path.display());
}
