//! Tiered-store capacity sweep: tail latency and hit rate as the hot
//! tier shrinks under the crossbars.
//!
//! Builds a ReCross offline phase over a synthetic Zipf window, then
//! serves two open-loop workloads through the [`Tiered`] backend — a
//! *steady* Zipf(1.1) stream matching the offline history, and a
//! *drifting* stream whose popularity order rotates mid-drive (the tier
//! replanner has to chase it) — across a sweep of hot-tier capacities
//! from everything-fits down to 5% of the groups. Each point gates on
//! the bit-identity contract (the tiered reduction equals the flat
//! store's reference reduction) before any timing is trusted, and
//! records the tier hit mix plus p50/p99 sojourn from `loadgen::drive`.
//!
//! Writes **`BENCH_tier.json`** (schema `recross.bench_tier` v1) at the
//! repository root: the acceptance artifact showing p99 degrading
//! *gracefully* — not cliff-like — as capacity shrinks. CI runs
//! `--smoke`, validates the schema, gates tracked p99 metrics through
//! `tools/perf_gate.py`, and uploads the file as an artifact.

use recross::allocation::group_frequencies;
use recross::config::Config;
use recross::coordinator::{BatchPolicy, EmbeddingStore};
use recross::deploy::{SimBackend, Tiered};
use recross::engine::{Engine, Scheme};
use recross::graph::CoGraph;
use recross::loadgen::{drive, Arrivals};
use recross::store::{TierCostModel, TierPolicy, TieredStore};
use recross::util::{Rng, Zipf};
use recross::workload::{Query, Trace};
use std::time::Duration;

/// Hot-tier capacities swept, as fractions of the group count, largest
/// first so the JSON reads as a degradation curve.
const HOT_FRACTIONS: [f64; 5] = [1.0, 0.5, 0.25, 0.1, 0.05];

struct Shape {
    embeddings: usize,
    group_size: usize,
    window_queries: usize,
    drive_queries: usize,
    pooling: usize,
    rate_qps: f64,
}

fn shape(smoke: bool) -> Shape {
    if smoke {
        Shape {
            embeddings: 1024,
            group_size: 16,
            window_queries: 512,
            drive_queries: 256,
            pooling: 8,
            rate_qps: 150_000.0,
        }
    } else {
        Shape {
            embeddings: 8192,
            group_size: 32,
            window_queries: 4096,
            drive_queries: 2048,
            pooling: 16,
            rate_qps: 150_000.0,
        }
    }
}

fn zipf_queries(
    rng: &mut Rng,
    zipf: &Zipf,
    perm: &[u32],
    queries: usize,
    pooling: usize,
) -> Vec<Query> {
    (0..queries)
        .map(|_| Query::new((0..pooling).map(|_| perm[zipf.sample(rng)]).collect()))
        .collect()
}

struct Point {
    label: String,
    workload: &'static str,
    hot_frac: f64,
    hot_tiles: usize,
    groups: usize,
    hit_rate: f64,
    hot_hits: u64,
    dram_hits: u64,
    cold_hits: u64,
    promotions: u64,
    evictions: u64,
    p50_ns: f64,
    p99_ns: f64,
    throughput_qps: f64,
}

#[allow(clippy::too_many_arguments)]
fn run_point(
    engine: &Engine,
    store: &EmbeddingStore,
    freqs: &[u64],
    workload: &'static str,
    queries: &[Query],
    hot_frac: f64,
    rate_qps: f64,
    seed: u64,
) -> Point {
    let mapping = engine.mapping();
    let groups = mapping.num_groups();
    let hot_tiles = ((groups as f64 * hot_frac).round() as usize).max(1);
    let policy = TierPolicy::new(hot_tiles, 0, 2);
    let cost = TierCostModel::new(120.0, 2_500.0);
    let tiered = TieredStore::build(store, freqs, policy, cost);

    // Correctness gate: a latency curve over wrong reductions is
    // worthless. Bitwise equality against the flat reference walk.
    for q in queries.iter().take(16) {
        let got = tiered.reduce(mapping, &q.items);
        let want = store.reduce_reference(&q.items);
        assert!(
            got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
            "{workload} hot={hot_frac}: tiered reduction diverged from flat store"
        );
    }

    let backend = Tiered::new(SimBackend::of_engine(engine), mapping, tiered, 8);
    let arrivals = Arrivals::poisson(rate_qps, seed).take(queries.len());
    let batch = BatchPolicy {
        max_batch: 16,
        max_wait: Duration::from_micros(5),
    };
    let report = drive(&backend, queries, &arrivals, &batch);
    let access = backend.access();
    let (promotions, evictions) = backend.moves();
    Point {
        label: format!("{workload}/hot-{}pct", (hot_frac * 100.0).round() as u32),
        workload,
        hot_frac,
        hot_tiles,
        groups,
        hit_rate: access.hit_rate(),
        hot_hits: access.hot_hits,
        dram_hits: access.dram_hits,
        cold_hits: access.cold_hits,
        promotions,
        evictions,
        p50_ns: report.percentile_ns(50.0),
        p99_ns: report.percentile_ns(99.0),
        throughput_qps: report.throughput_qps(),
    }
}

fn json(points: &[Point], smoke: bool) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"schema\": \"recross.bench_tier\",\n");
    out.push_str("  \"version\": 1,\n");
    out.push_str("  \"bench\": \"tiered_store\",\n");
    out.push_str(&format!(
        "  \"mode\": \"{}\",\n",
        if smoke { "smoke" } else { "full" }
    ));
    out.push_str("  \"points\": [\n");
    for (i, p) in points.iter().enumerate() {
        out.push_str("    {\n");
        out.push_str(&format!(
            "      \"label\": \"{}\", \"workload\": \"{}\",\n",
            p.label, p.workload
        ));
        out.push_str(&format!(
            "      \"hot_frac\": {:.2}, \"hot_tiles\": {}, \"groups\": {},\n",
            p.hot_frac, p.hot_tiles, p.groups
        ));
        out.push_str(&format!(
            "      \"hit_rate\": {:.4}, \"hot_hits\": {}, \"dram_hits\": {}, \
             \"cold_hits\": {},\n",
            p.hit_rate, p.hot_hits, p.dram_hits, p.cold_hits
        ));
        out.push_str(&format!(
            "      \"promotions\": {}, \"evictions\": {},\n",
            p.promotions, p.evictions
        ));
        out.push_str(&format!(
            "      \"p50_ns\": {:.1}, \"p99_ns\": {:.1}, \"throughput_qps\": {:.1}\n",
            p.p50_ns, p.p99_ns, p.throughput_qps
        ));
        out.push_str(if i + 1 == points.len() {
            "    }\n"
        } else {
            "    },\n"
        });
    }
    out.push_str("  ]\n}\n");
    out
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let sh = shape(smoke);
    let mut cfg = Config::paper_default();
    cfg.scheme.group_size = sh.group_size;
    cfg.scheme.batch_size = 256;

    let mut rng = Rng::new(0x71E7_ED);
    let zipf = Zipf::new(sh.embeddings, 1.1);
    let base: Vec<u32> = (0..sh.embeddings as u32).collect();
    // The drifted order rotates popularity by a third of the catalogue:
    // yesterday's torso becomes today's head.
    let drifted: Vec<u32> = (0..sh.embeddings as u32)
        .map(|i| (i + sh.embeddings as u32 / 3) % sh.embeddings as u32)
        .collect();

    let window = Trace {
        num_embeddings: sh.embeddings as u32,
        queries: zipf_queries(&mut rng, &zipf, &base, sh.window_queries, sh.pooling),
    };
    let engine = Engine::prepare(Scheme::ReCross, &CoGraph::build(&window), &window, &cfg);
    let mapping = engine.mapping();
    let store = EmbeddingStore::random(
        mapping,
        cfg.hardware.embedding_dim,
        cfg.hardware.xbar_rows,
        42,
    );
    let freqs = group_frequencies(mapping, &window);

    // Steady: the offline distribution continues. Drifting: halfway
    // through the drive the popularity order rotates out from under the
    // hot set and the replanner has to chase it.
    let steady = zipf_queries(&mut rng, &zipf, &base, sh.drive_queries, sh.pooling);
    let mut drifting = zipf_queries(&mut rng, &zipf, &base, sh.drive_queries / 2, sh.pooling);
    drifting.extend(zipf_queries(
        &mut rng,
        &zipf,
        &drifted,
        sh.drive_queries - sh.drive_queries / 2,
        sh.pooling,
    ));

    println!(
        "== tiered store: capacity sweep, {} mode, {} groups ==\n",
        if smoke { "smoke" } else { "full" },
        mapping.num_groups()
    );
    println!(
        "{:<22} {:>6} {:>9} {:>12} {:>12} {:>8} {:>8}",
        "point", "tiles", "hit rate", "p50 ns", "p99 ns", "promote", "evict"
    );

    let mut points = Vec::new();
    for (workload, queries) in [("zipf", &steady), ("drifting-zipf", &drifting)] {
        for (i, &frac) in HOT_FRACTIONS.iter().enumerate() {
            let p = run_point(
                &engine,
                &store,
                &freqs,
                workload,
                queries,
                frac,
                sh.rate_qps,
                0xA11 + i as u64,
            );
            println!(
                "{:<22} {:>6} {:>8.1}% {:>12.0} {:>12.0} {:>8} {:>8}",
                p.label,
                p.hot_tiles,
                100.0 * p.hit_rate,
                p.p50_ns,
                p.p99_ns,
                p.promotions,
                p.evictions
            );
            points.push(p);
        }
    }

    // Graceful-degradation gate on the steady sweep: with everything
    // hot, misses must cost nothing; as capacity shrinks the tail may
    // only grow (monotone within measurement noise — 2x headroom).
    let steady_p99: Vec<f64> = points
        .iter()
        .filter(|p| p.workload == "zipf")
        .map(|p| p.p99_ns)
        .collect();
    assert!(
        points[0].hit_rate > 0.999,
        "everything-fits point recorded tier misses (hit rate {})",
        points[0].hit_rate
    );
    for w in steady_p99.windows(2) {
        assert!(
            w[1] >= w[0] / 2.0,
            "p99 fell off a cliff between adjacent capacities: {} -> {}",
            w[0],
            w[1]
        );
    }

    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_tier.json");
    std::fs::write(&path, json(&points, smoke)).expect("writing BENCH_tier.json");
    println!("\nwrote {}", path.display());
}
