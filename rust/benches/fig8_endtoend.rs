//! Bench for paper Fig. 8: end-to-end completion time + energy of
//! ReCross vs naive vs nMARS on all five workloads.
//!
//! Prints (a) criterion-style wall-clock timings of the simulator itself
//! and (b) the regenerated Fig. 8 table (the paper's metric). Scale is
//! set by RECROSS_BENCH_SCALE (default 0.1).

use recross::engine::Scheme;
use recross::report::{self, Workbench};
use recross::util::bench::{black_box, Bench, BenchConfig};
use std::time::Duration;

fn scale() -> f64 {
    std::env::var("RECROSS_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.1)
}

fn main() {
    let scale = scale();
    println!("== fig8 end-to-end bench (scale {scale}) ==\n");
    let mut wb = Workbench::at_scale(scale);

    // Prepare everything once (offline phase), then measure the online
    // phase (run_trace) — the paper's completion-time metric comes from
    // exactly this code path.
    let mut bench = Bench::with_config(BenchConfig {
        warmup: Duration::from_millis(200),
        measure: Duration::from_secs(1),
        max_iters: 50,
        min_iters: 3,
    });
    for ds in ["software", "automotive"] {
        for scheme in Scheme::fig8_set() {
            // compare() caches engines; re-running measures the simulator.
            bench.run(&format!("sim/{ds}/{}", scheme.name()), || {
                black_box(wb.compare(ds, [scheme]))
            });
        }
    }

    println!("\n{}", report::fig8(&mut wb));
    let _ = bench.write_tsv("target/bench_fig8.tsv");
}
