//! Bench for paper Fig. 9: crossbar activation counts of naive /
//! frequency-based / ReCross mappings on all five workloads, plus the
//! wall-clock cost of the offline phase (graph build + Algorithm 1) that
//! produces them.

use recross::config::Config;
use recross::engine::{Engine, Scheme};
use recross::graph::CoGraph;
use recross::report::{self, Workbench};
use recross::util::bench::{black_box, Bench, BenchConfig};
use recross::workload::{generate, DatasetSpec};
use std::time::Duration;

fn scale() -> f64 {
    std::env::var("RECROSS_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.1)
}

fn main() {
    let scale = scale();
    println!("== fig9 activation bench (scale {scale}) ==\n");

    // Offline-phase cost on one mid-size dataset.
    let spec = DatasetSpec::by_name("software").unwrap().scaled(scale);
    let (history, eval) = generate(&spec, 4_000, 1_024, 42);
    let cfg = Config::paper_default();
    let mut bench = Bench::with_config(BenchConfig {
        warmup: Duration::from_millis(200),
        measure: Duration::from_secs(1),
        max_iters: 20,
        min_iters: 3,
    });
    bench.run("offline/cograph-build", || {
        black_box(CoGraph::build(&history))
    });
    let graph = CoGraph::build(&history);
    bench.run("offline/algorithm1-grouping", || {
        black_box(Engine::prepare(Scheme::ReCross, &graph, &history, &cfg))
    });
    let engine = Engine::prepare(Scheme::ReCross, &graph, &history, &cfg);
    bench.run("online/count-activations", || {
        black_box(engine.count_activations(&eval))
    });

    let mut wb = Workbench::at_scale(scale);
    println!("\n{}", report::fig9(&mut wb));
    let _ = bench.write_tsv("target/bench_fig9.tsv");
}
