//! Bench for paper Fig. 10: the duplication-ratio sweep (0/5/10/20% area
//! overhead) — effectiveness of access-aware crossbar allocation.

use recross::report::{self, Workbench};
use recross::util::bench::{black_box, Bench, BenchConfig};
use std::time::Duration;

fn scale() -> f64 {
    std::env::var("RECROSS_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.1)
}

fn main() {
    let scale = scale();
    println!("== fig10 duplication bench (scale {scale}) ==\n");
    let mut wb = Workbench::at_scale(scale);

    let mut bench = Bench::with_config(BenchConfig {
        warmup: Duration::from_millis(100),
        measure: Duration::from_secs(1),
        max_iters: 20,
        min_iters: 3,
    });
    bench.run("dup-sweep/automotive(4 ratios)", || {
        black_box(wb.dup_sweep("automotive", &[0.0, 0.05, 0.10, 0.20]))
    });

    println!("\n{}", report::fig10(&mut wb));
    println!("\n{}", report::ablation(&mut wb, "automotive"));
    let _ = bench.write_tsv("target/bench_fig10.tsv");
}
