//! Fig. 12 (extension): completion-time scaling of the sharded pool.
//!
//! Sweeps shard count × replication budget over the calibrated "software"
//! workload and reports the simulated batch completion time of the
//! scatter-gather cluster ([`recross::cluster::simulate_sharded`]), plus
//! the locality partitioner's fan-out, against the single-pool baseline
//! (shards = 1). Also measures the simulator's own wall time via the
//! in-tree bench harness.

use recross::allocation::group_frequencies;
use recross::cluster::{
    simulate_sharded, simulate_with_replicas, PoolShared, ReplicaPlan, RoutePolicy, ShardPlan,
};
use recross::config::Config;
use recross::engine::{Engine, Scheme};
use recross::graph::CoGraph;
use recross::util::bench::{black_box, Bench, BenchConfig};
use recross::util::fmt_ns;
use recross::workload::{generate, DatasetSpec};
use std::time::Duration;

fn main() {
    let spec = DatasetSpec::by_name("software").unwrap().scaled(0.1);
    let (history, eval) = generate(&spec, 3_000, 512, 42);
    let graph = CoGraph::build(&history);

    let mut bench = Bench::with_config(BenchConfig {
        warmup: Duration::from_millis(100),
        measure: Duration::from_millis(500),
        max_iters: 200,
        min_iters: 3,
    });

    println!("== fig12: sharding x replication sweep (software@0.1) ==\n");
    println!(
        "{:>6} {:>6} {:>12} {:>10} {:>10} {:>12}",
        "dup%", "shards", "completion", "speedup", "fan-out", "stall/subq"
    );
    for dup_ratio in [0.0, 0.10] {
        let mut cfg = Config::paper_default();
        cfg.scheme.dup_ratio = dup_ratio;
        let engine = Engine::prepare(Scheme::ReCross, &graph, &history, &cfg);
        let shared = PoolShared::from_engine(&engine);
        let mut baseline_ns = 0.0f64;
        for shards in [1usize, 2, 4, 8, 16] {
            let plan = ShardPlan::by_locality(&shared.mapping, &history, shards, 0.10);
            let stats = simulate_sharded(&shared, &plan, &eval, cfg.scheme.batch_size);
            if shards == 1 {
                baseline_ns = stats.completion_ns;
            }
            let fanout = plan.fanout_histogram(&shared.mapping, &eval).mean();
            // Queue wait per sub-query: completion is a max-merge across
            // shards while stall_ns sums, so a ratio of the two would
            // inflate with shard count instead of measuring contention.
            let stall_per_subq = stats.stall_ns / stats.queries.max(1) as f64;
            println!(
                "{:>5.0}% {:>6} {:>12} {:>9.2}x {:>10.2} {:>12}",
                dup_ratio * 100.0,
                shards,
                fmt_ns(stats.completion_ns),
                baseline_ns / stats.completion_ns.max(1e-9),
                fanout,
                fmt_ns(stall_per_subq)
            );
        }
    }

    // --- replica routing vs ownership-pinned placement -------------------
    // The tentpole comparison: same plan, same Eq. 1 copies, but spread
    // across shards and routed by power-of-two-choices.
    println!("\n== replica placement: pinned vs p2c-routed (dup 10%) ==\n");
    println!(
        "{:>6} {:>14} {:>14} {:>9} {:>12} {:>12}",
        "shards", "pin-maxload", "rt-maxload", "delta", "pin-compl", "rt-compl"
    );
    {
        let cfg = Config::paper_default(); // dup_ratio 0.10
        let engine = Engine::prepare(Scheme::ReCross, &graph, &history, &cfg);
        let shared = PoolShared::from_engine(&engine);
        let freqs = group_frequencies(&shared.mapping, &history);
        for shards in [2usize, 4, 8, 16] {
            let plan = ShardPlan::by_locality(&shared.mapping, &history, shards, 0.10);
            let pinned_plan = ReplicaPlan::pinned(&plan, &shared.replication);
            let spread_plan = ReplicaPlan::spread(&plan, &shared.replication, &freqs);
            let pinned = simulate_with_replicas(
                &shared,
                &plan,
                &pinned_plan,
                &eval,
                cfg.scheme.batch_size,
                RoutePolicy::Pinned,
            );
            let routed = simulate_with_replicas(
                &shared,
                &plan,
                &spread_plan,
                &eval,
                cfg.scheme.batch_size,
                RoutePolicy::PowerOfTwo,
            );
            let delta = 100.0
                * (1.0 - routed.max_shard_load() as f64 / pinned.max_shard_load().max(1) as f64);
            println!(
                "{:>6} {:>14} {:>14} {:>8.1}% {:>12} {:>12}",
                shards,
                pinned.max_shard_load(),
                routed.max_shard_load(),
                delta,
                fmt_ns(pinned.stats.completion_ns),
                fmt_ns(routed.stats.completion_ns)
            );
        }
    }

    println!("\n== simulator wall time ==");
    let cfg = Config::paper_default();
    let engine = Engine::prepare(Scheme::ReCross, &graph, &history, &cfg);
    let shared = PoolShared::from_engine(&engine);
    for shards in [1usize, 4, 16] {
        let plan = ShardPlan::by_locality(&shared.mapping, &history, shards, 0.10);
        bench.run(&format!("fig12/simulate_sharded(shards={shards})"), || {
            black_box(simulate_sharded(
                &shared,
                &plan,
                &eval,
                cfg.scheme.batch_size,
            ))
        });
    }
    let _ = bench.write_tsv("target/bench_fig12.tsv");
}
