//! Bench for the paper's analysis figures: Fig. 2 (co-occurrence power
//! law), Fig. 4 (post-grouping access distribution), Fig. 5 (log-scaling
//! copy distribution), Fig. 6 (single-embedding activation share).

use recross::report::{self, Workbench};
use recross::util::bench::{black_box, Bench, BenchConfig};
use std::time::Duration;

fn scale() -> f64 {
    std::env::var("RECROSS_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.1)
}

fn main() {
    let scale = scale();
    println!("== analysis figures bench (scale {scale}) ==\n");
    let mut wb = Workbench::at_scale(scale);

    // Warm the caches (dataset generation dominates otherwise).
    let _ = wb.dataset("software");
    let _ = wb.dataset("automotive");

    let mut bench = Bench::with_config(BenchConfig {
        warmup: Duration::from_millis(100),
        measure: Duration::from_secs(1),
        max_iters: 10,
        min_iters: 2,
    });
    bench.run("report/fig4", || black_box(report::fig4(&mut wb)));
    bench.run("report/fig5", || black_box(report::fig5(&mut wb)));

    println!("\n{}", report::fig2(&mut wb));
    println!("{}", report::fig4(&mut wb));
    println!("{}", report::fig5(&mut wb));
    println!("{}", report::fig6(&mut wb));
    let _ = bench.write_tsv("target/bench_analysis.tsv");
}
