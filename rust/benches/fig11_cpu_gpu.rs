//! Bench for paper Fig. 11: energy efficiency of ReCross versus the
//! CPU-only and CPU+GPU host platforms (analytical models; see DESIGN.md
//! §Substitutions).

use recross::energy::{HostModel, HostPlatform};
use recross::report::{self, Workbench};
use recross::util::bench::{black_box, Bench, BenchConfig};
use recross::workload::{generate, DatasetSpec};
use recross::xbar::HostParams;
use std::time::Duration;

fn scale() -> f64 {
    std::env::var("RECROSS_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.1)
}

fn main() {
    let scale = scale();
    println!("== fig11 host-platform bench (scale {scale}) ==\n");

    let spec = DatasetSpec::by_name("electronics").unwrap().scaled(scale);
    let (_, eval) = generate(&spec, 1_000, 2_048, 42);
    let host = HostModel::new(&HostParams::default(), 16);
    let mut bench = Bench::with_config(BenchConfig {
        warmup: Duration::from_millis(100),
        measure: Duration::from_millis(500),
        max_iters: 1000,
        min_iters: 5,
    });
    bench.run("host-model/cpu", || {
        black_box(host.run_trace(&eval, HostPlatform::CpuOnly))
    });
    bench.run("host-model/cpu+gpu", || {
        black_box(host.run_trace(&eval, HostPlatform::CpuGpu))
    });

    let mut wb = Workbench::at_scale(scale);
    println!("\n{}", report::fig11(&mut wb));
    let _ = bench.write_tsv("target/bench_fig11.tsv");
}
