//! Scheduler hot-path throughput: data-oriented vs reference, with a
//! machine-readable perf trajectory.
//!
//! Sweeps {replica copies, bus channels, batch size, pooling factor}
//! over synthetic Zipf workloads, runs both the optimized scheduler
//! (`sched::Scheduler`: tournament-tree slot selection, sort-free run
//! decomposition) and the preserved naive loop
//! (`sched::ReferenceScheduler`), asserts their schedules are
//! bit-identical, and writes **`BENCH_sched.json`** at the repository
//! root: per config, simulated-queries/second and slot-comparison counts
//! for both implementations (schema in DESIGN.md §"Simulator
//! performance"). CI runs `--smoke` (seconds-scale) on every push and
//! uploads the file as an artifact, so the perf trajectory accumulates
//! across PRs.

use recross::allocation::{self, Replication};
use recross::config::HardwareConfig;
use recross::grouping::Mapping;
use recross::sched::{ReferenceScheduler, ReferenceScratch, Scheduler, Scratch};
use recross::util::bench::black_box;
use recross::util::{Rng, Zipf};
use recross::workload::{Query, Trace};
use recross::xbar::{CircuitParams, CrossbarModel};
use std::time::Instant;

/// One sweep point. `copies = 0` means "plan by Eq. 1" (dup_ratio 0.10,
/// the paper's default budget); otherwise every group gets exactly
/// `copies` replicas so the replica-scan length is an explicit knob.
#[derive(Clone, Copy)]
struct SweepPoint {
    name: &'static str,
    groups: usize,
    copies: u32,
    bus_channels: usize,
    batch: usize,
    pooling: usize,
}

const GROUP_SIZE: usize = 64;

fn pt(
    name: &'static str,
    groups: usize,
    copies: u32,
    bus_channels: usize,
    batch: usize,
    pooling: usize,
) -> SweepPoint {
    SweepPoint {
        name,
        groups,
        copies,
        bus_channels,
        batch,
        pooling,
    }
}

fn full_points() -> Vec<SweepPoint> {
    // Paper-like baseline first: Eq. 1 copies (<= ~5), 16 channels. Both
    // slot tables stay on the flat fast path — this row is the
    // no-regression evidence for tiny configs.
    let mut pts = vec![pt("eq1-base", 1024, 0, 16, 256, 32)];
    for &c in &[2u32, 8, 32, 128] {
        pts.push(pt("copies", 512, c, 32, 256, 32));
    }
    for &b in &[8usize, 64, 256] {
        pts.push(pt("bus", 512, 8, b, 256, 32));
    }
    for &n in &[64usize, 1024] {
        pts.push(pt("batch", 512, 32, 64, n, 32));
    }
    for &p in &[8usize, 128] {
        pts.push(pt("pooling", 512, 32, 64, 256, p));
    }
    pts
}

fn smoke_points() -> Vec<SweepPoint> {
    vec![
        pt("eq1-base", 128, 0, 16, 64, 16),
        pt("copies", 128, 64, 32, 64, 16),
        pt("bus", 128, 8, 128, 64, 16),
        pt("pooling", 128, 32, 64, 64, 64),
    ]
}

/// Mean wall-clock ns per call of `f`, with warm-up.
fn measure<F: FnMut()>(mut f: F, measure_ns: u64, min_iters: u64) -> f64 {
    let warm = Instant::now();
    let warm_budget = std::time::Duration::from_nanos(measure_ns / 4);
    let mut warm_iters = 0u64;
    while warm.elapsed() < warm_budget || warm_iters < 2 {
        f();
        warm_iters += 1;
    }
    let start = Instant::now();
    let budget = std::time::Duration::from_nanos(measure_ns);
    let mut iters = 0u64;
    while start.elapsed() < budget || iters < min_iters {
        f();
        iters += 1;
    }
    start.elapsed().as_nanos() as f64 / iters as f64
}

struct Side {
    qps: f64,
    ns_per_batch: f64,
    comparisons: u64,
}

struct Row {
    point: SweepPoint,
    physical: usize,
    max_copies: u32,
    reference: Side,
    optimized: Side,
}

fn run_point(pt: &SweepPoint, measure_ns: u64, seed: u64) -> Row {
    let n = pt.groups * GROUP_SIZE;
    let groups: Vec<Vec<u32>> = (0..pt.groups)
        .map(|g| ((g * GROUP_SIZE) as u32..((g + 1) * GROUP_SIZE) as u32).collect())
        .collect();
    let map = Mapping::from_groups(groups, GROUP_SIZE, n);

    // Zipf item popularity: low ids are hot, so low groups are hot —
    // the same skew Eq. 1 is designed around.
    let mut rng = Rng::new(seed);
    let zipf = Zipf::new(n, 1.05);
    let queries: Vec<Query> = (0..pt.batch)
        .map(|_| Query::new((0..pt.pooling).map(|_| zipf.sample(&mut rng) as u32).collect()))
        .collect();

    let rep = if pt.copies == 0 {
        let trace = Trace {
            num_embeddings: n as u32,
            queries: queries.clone(),
        };
        let freqs = allocation::group_frequencies(&map, &trace);
        allocation::plan_replication(&freqs, pt.batch, 0.10)
    } else {
        Replication::from_copies(vec![pt.copies; pt.groups], pt.batch)
    };
    let hw = HardwareConfig {
        bus_channels: pt.bus_channels,
        ..Default::default()
    };
    let model = CrossbarModel::new(&hw, &CircuitParams::default());

    let opt = Scheduler::new(&map, &rep, &model, true);
    let naive = ReferenceScheduler::new(&map, &rep, &model, true);
    let mut scratch = Scratch::default();
    let mut rscratch = ReferenceScratch::default();

    // Correctness gate: a benchmark of a wrong scheduler is worthless.
    let a = opt.run_batch(&queries, &mut scratch);
    let b = naive.run_batch(&queries, &mut rscratch);
    assert_eq!(a, b, "{}: optimized and reference schedules diverged", pt.name);

    // Deterministic comparison counts for exactly one batch.
    scratch.reset_comparisons();
    rscratch.reset_comparisons();
    opt.run_batch(&queries, &mut scratch);
    naive.run_batch(&queries, &mut rscratch);
    let opt_cmps = scratch.comparisons();
    let ref_cmps = rscratch.comparisons();

    let opt_ns = measure(
        || {
            black_box(opt.run_batch(&queries, &mut scratch));
        },
        measure_ns,
        3,
    );
    let ref_ns = measure(
        || {
            black_box(naive.run_batch(&queries, &mut rscratch));
        },
        measure_ns,
        3,
    );

    let side = |ns_per_batch: f64, comparisons: u64| Side {
        qps: pt.batch as f64 / (ns_per_batch / 1e9),
        ns_per_batch,
        comparisons,
    };
    Row {
        point: *pt,
        physical: rep.total_crossbars,
        max_copies: rep.copies.iter().copied().max().unwrap_or(1),
        reference: side(ref_ns, ref_cmps),
        optimized: side(opt_ns, opt_cmps),
    }
}

fn json(rows: &[Row], smoke: bool) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"bench\": \"sched_throughput\",\n");
    out.push_str("  \"version\": 1,\n");
    out.push_str(&format!("  \"mode\": \"{}\",\n", if smoke { "smoke" } else { "full" }));
    out.push_str("  \"configs\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let p = &r.point;
        out.push_str("    {\n");
        out.push_str(&format!("      \"name\": \"{}\",\n", p.name));
        out.push_str(&format!(
            "      \"groups\": {}, \"group_size\": {GROUP_SIZE}, \"copies\": {}, \
             \"max_copies\": {}, \"physical_crossbars\": {},\n",
            p.groups, p.copies, r.max_copies, r.physical
        ));
        out.push_str(&format!(
            "      \"bus_channels\": {}, \"batch\": {}, \"pooling\": {},\n",
            p.bus_channels, p.batch, p.pooling
        ));
        for (key, s) in [("reference", &r.reference), ("optimized", &r.optimized)] {
            out.push_str(&format!(
                "      \"{key}\": {{\"sim_queries_per_sec\": {:.1}, \"ns_per_batch\": {:.1}, \
                 \"comparisons_per_batch\": {}}},\n",
                s.qps, s.ns_per_batch, s.comparisons
            ));
        }
        out.push_str(&format!(
            "      \"speedup\": {:.3},\n      \"comparison_ratio\": {:.3}\n",
            r.reference.ns_per_batch / r.optimized.ns_per_batch,
            r.reference.comparisons as f64 / (r.optimized.comparisons.max(1)) as f64
        ));
        out.push_str(if i + 1 == rows.len() { "    }\n" } else { "    },\n" });
    }
    out.push_str("  ]\n}\n");
    out
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (points, measure_ns) = if smoke {
        (smoke_points(), 60_000_000u64) // 60 ms/side/config: seconds total
    } else {
        (full_points(), 1_000_000_000u64)
    };

    println!(
        "== scheduler throughput: optimized (tree) vs reference (scan), {} mode ==\n",
        if smoke { "smoke" } else { "full" }
    );
    println!(
        "{:<10} {:>7} {:>6} {:>5} {:>6} {:>8} {:>12} {:>12} {:>8} {:>10}",
        "config", "groups", "copies", "bus", "batch", "pooling", "ref q/s", "opt q/s",
        "speedup", "cmp ratio"
    );

    let mut rows = Vec::new();
    for (i, pt) in points.iter().enumerate() {
        let row = run_point(pt, measure_ns, 0xBE11C + i as u64);
        println!(
            "{:<10} {:>7} {:>6} {:>5} {:>6} {:>8} {:>12.0} {:>12.0} {:>7.2}x {:>9.1}x",
            pt.name,
            pt.groups,
            row.max_copies,
            pt.bus_channels,
            pt.batch,
            pt.pooling,
            row.reference.qps,
            row.optimized.qps,
            row.reference.ns_per_batch / row.optimized.ns_per_batch,
            row.reference.comparisons as f64 / row.optimized.comparisons.max(1) as f64,
        );
        rows.push(row);
    }

    // The perf trajectory lands at the repository root so it diffs and
    // uploads uniformly across PRs regardless of cargo's working dir.
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_sched.json");
    std::fs::write(&path, json(&rows, smoke)).expect("writing BENCH_sched.json");
    println!("\nwrote {}", path.display());
}
