//! Scheduler hot-path throughput: data-oriented vs reference, with a
//! machine-readable perf trajectory.
//!
//! Sweeps {replica copies, bus channels, batch size, pooling factor}
//! over synthetic Zipf workloads, runs both the optimized scheduler
//! (`sched::Scheduler`: tournament-tree slot selection, sort-free run
//! decomposition) and the preserved naive loop
//! (`sched::ReferenceScheduler`), asserts their schedules are
//! bit-identical, and writes **`BENCH_sched.json`** at the repository
//! root: per config, simulated-queries/second and slot-comparison counts
//! for both implementations (schema v2 in DESIGN.md §"Parallel offline
//! phase & SIMD kernels"). A second sweep times the f32 reduce kernel —
//! the SIMD `add_assign_4wide` dispatch vs a naive scalar loop, gated on
//! bit-identity — and lands as the top-level `"reduce"` array (SIMD
//! lanes are the data-parallel axis here; the scheduler itself stays
//! serial). CI runs `--smoke` (seconds-scale) on every push, feeds the
//! file through `tools/perf_gate.py`, and uploads it as an artifact, so
//! the perf trajectory accumulates across PRs.

use recross::allocation::{self, Replication};
use recross::config::HardwareConfig;
use recross::grouping::Mapping;
use recross::sched::{ReferenceScheduler, ReferenceScratch, Scheduler, Scratch};
use recross::util::bench::black_box;
use recross::util::{Rng, Zipf};
use recross::workload::{Query, Trace};
use recross::xbar::{CircuitParams, CrossbarModel};
use std::time::Instant;

/// One sweep point. `copies = 0` means "plan by Eq. 1" (dup_ratio 0.10,
/// the paper's default budget); otherwise every group gets exactly
/// `copies` replicas so the replica-scan length is an explicit knob.
#[derive(Clone, Copy)]
struct SweepPoint {
    name: &'static str,
    groups: usize,
    copies: u32,
    bus_channels: usize,
    batch: usize,
    pooling: usize,
}

const GROUP_SIZE: usize = 64;

fn pt(
    name: &'static str,
    groups: usize,
    copies: u32,
    bus_channels: usize,
    batch: usize,
    pooling: usize,
) -> SweepPoint {
    SweepPoint {
        name,
        groups,
        copies,
        bus_channels,
        batch,
        pooling,
    }
}

fn full_points() -> Vec<SweepPoint> {
    // Paper-like baseline first: Eq. 1 copies (<= ~5), 16 channels. Both
    // slot tables stay on the flat fast path — this row is the
    // no-regression evidence for tiny configs.
    let mut pts = vec![pt("eq1-base", 1024, 0, 16, 256, 32)];
    for &c in &[2u32, 8, 32, 128] {
        pts.push(pt("copies", 512, c, 32, 256, 32));
    }
    for &b in &[8usize, 64, 256] {
        pts.push(pt("bus", 512, 8, b, 256, 32));
    }
    for &n in &[64usize, 1024] {
        pts.push(pt("batch", 512, 32, 64, n, 32));
    }
    for &p in &[8usize, 128] {
        pts.push(pt("pooling", 512, 32, 64, 256, p));
    }
    pts
}

fn smoke_points() -> Vec<SweepPoint> {
    vec![
        pt("eq1-base", 128, 0, 16, 64, 16),
        pt("copies", 128, 64, 32, 64, 16),
        pt("bus", 128, 8, 128, 64, 16),
        pt("pooling", 128, 32, 64, 64, 64),
    ]
}

/// Mean wall-clock ns per call of `f`, with warm-up.
fn measure<F: FnMut()>(mut f: F, measure_ns: u64, min_iters: u64) -> f64 {
    let warm = Instant::now();
    let warm_budget = std::time::Duration::from_nanos(measure_ns / 4);
    let mut warm_iters = 0u64;
    while warm.elapsed() < warm_budget || warm_iters < 2 {
        f();
        warm_iters += 1;
    }
    let start = Instant::now();
    let budget = std::time::Duration::from_nanos(measure_ns);
    let mut iters = 0u64;
    while start.elapsed() < budget || iters < min_iters {
        f();
        iters += 1;
    }
    start.elapsed().as_nanos() as f64 / iters as f64
}

struct Side {
    qps: f64,
    ns_per_batch: f64,
    comparisons: u64,
}

struct Row {
    point: SweepPoint,
    physical: usize,
    max_copies: u32,
    reference: Side,
    optimized: Side,
}

fn run_point(pt: &SweepPoint, measure_ns: u64, seed: u64) -> Row {
    let n = pt.groups * GROUP_SIZE;
    let groups: Vec<Vec<u32>> = (0..pt.groups)
        .map(|g| ((g * GROUP_SIZE) as u32..((g + 1) * GROUP_SIZE) as u32).collect())
        .collect();
    let map = Mapping::from_groups(groups, GROUP_SIZE, n);

    // Zipf item popularity: low ids are hot, so low groups are hot —
    // the same skew Eq. 1 is designed around.
    let mut rng = Rng::new(seed);
    let zipf = Zipf::new(n, 1.05);
    let queries: Vec<Query> = (0..pt.batch)
        .map(|_| Query::new((0..pt.pooling).map(|_| zipf.sample(&mut rng) as u32).collect()))
        .collect();

    let rep = if pt.copies == 0 {
        let trace = Trace {
            num_embeddings: n as u32,
            queries: queries.clone(),
        };
        let freqs = allocation::group_frequencies(&map, &trace);
        allocation::plan_replication(&freqs, pt.batch, 0.10)
    } else {
        Replication::from_copies(vec![pt.copies; pt.groups], pt.batch)
    };
    let hw = HardwareConfig {
        bus_channels: pt.bus_channels,
        ..Default::default()
    };
    let model = CrossbarModel::new(&hw, &CircuitParams::default());

    let opt = Scheduler::new(&map, &rep, &model, true);
    let naive = ReferenceScheduler::new(&map, &rep, &model, true);
    let mut scratch = Scratch::default();
    let mut rscratch = ReferenceScratch::default();

    // Correctness gate: a benchmark of a wrong scheduler is worthless.
    let a = opt.run_batch(&queries, &mut scratch);
    let b = naive.run_batch(&queries, &mut rscratch);
    assert_eq!(a, b, "{}: optimized and reference schedules diverged", pt.name);

    // Deterministic comparison counts for exactly one batch.
    scratch.reset_comparisons();
    rscratch.reset_comparisons();
    opt.run_batch(&queries, &mut scratch);
    naive.run_batch(&queries, &mut rscratch);
    let opt_cmps = scratch.comparisons();
    let ref_cmps = rscratch.comparisons();

    let opt_ns = measure(
        || {
            black_box(opt.run_batch(&queries, &mut scratch));
        },
        measure_ns,
        3,
    );
    let ref_ns = measure(
        || {
            black_box(naive.run_batch(&queries, &mut rscratch));
        },
        measure_ns,
        3,
    );

    let side = |ns_per_batch: f64, comparisons: u64| Side {
        qps: pt.batch as f64 / (ns_per_batch / 1e9),
        ns_per_batch,
        comparisons,
    };
    Row {
        point: *pt,
        physical: rep.total_crossbars,
        max_copies: rep.copies.iter().copied().max().unwrap_or(1),
        reference: side(ref_ns, ref_cmps),
        optimized: side(opt_ns, opt_cmps),
    }
}

/// One reduce-kernel measurement: the SIMD `add_assign_4wide` dispatch
/// vs a naive scalar loop, summing `rows` embedding rows of width `dim`
/// into one accumulator.
struct ReduceRow {
    name: &'static str,
    dim: usize,
    rows: usize,
    scalar_ns: f64,
    simd_ns: f64,
}

/// The widest lane set the dispatching entry point resolves to on this
/// host (mirrors `util::accum`'s feature-detection order).
fn reduce_kernel_name() -> &'static str {
    #[cfg(target_arch = "x86_64")]
    {
        if std::is_x86_feature_detected!("avx2") {
            "avx2"
        } else {
            "sse2"
        }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        "blocked"
    }
}

fn run_reduce_point(
    name: &'static str,
    dim: usize,
    rows: usize,
    measure_ns: u64,
    seed: u64,
) -> ReduceRow {
    use recross::util::accum::add_assign_4wide;
    let mut rng = Rng::new(seed);
    let table: Vec<Vec<f32>> = (0..rows)
        .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
        .collect();

    let scalar = |out: &mut [f32]| {
        for r in &table {
            for (o, &s) in out.iter_mut().zip(r) {
                *o += s;
            }
        }
    };
    let simd = |out: &mut [f32]| {
        for r in &table {
            add_assign_4wide(out, r);
        }
    };

    // Correctness gate: the SIMD dispatch must match the scalar loop
    // bit-for-bit before its timing means anything.
    let mut a = vec![0.0f32; dim];
    let mut b = vec![0.0f32; dim];
    scalar(&mut a);
    simd(&mut b);
    assert_eq!(a, b, "{name}: SIMD reduce diverged from scalar");

    let mut acc = vec![0.0f32; dim];
    let scalar_ns = measure(
        || {
            acc.fill(0.0);
            scalar(&mut acc);
            black_box(&acc);
        },
        measure_ns,
        3,
    );
    let simd_ns = measure(
        || {
            acc.fill(0.0);
            simd(&mut acc);
            black_box(&acc);
        },
        measure_ns,
        3,
    );
    ReduceRow {
        name,
        dim,
        rows,
        scalar_ns,
        simd_ns,
    }
}

/// Reduce sweep: the paper dim (16), a wide dim hitting the 8-lane path
/// hard (64), and an odd dim exercising every remainder tail (67).
fn reduce_points(smoke: bool) -> Vec<(&'static str, usize, usize)> {
    if smoke {
        vec![("dim16", 16, 64), ("dim64", 64, 64), ("dim67-tail", 67, 64)]
    } else {
        vec![
            ("dim16", 16, 512),
            ("dim64", 64, 512),
            ("dim67-tail", 67, 512),
            ("dim256", 256, 512),
        ]
    }
}

fn json(rows: &[Row], reduce: &[ReduceRow], smoke: bool) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"bench\": \"sched_throughput\",\n");
    out.push_str("  \"version\": 2,\n");
    out.push_str(&format!("  \"mode\": \"{}\",\n", if smoke { "smoke" } else { "full" }));
    out.push_str("  \"configs\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let p = &r.point;
        out.push_str("    {\n");
        out.push_str(&format!("      \"name\": \"{}\",\n", p.name));
        out.push_str(&format!(
            "      \"groups\": {}, \"group_size\": {GROUP_SIZE}, \"copies\": {}, \
             \"max_copies\": {}, \"physical_crossbars\": {},\n",
            p.groups, p.copies, r.max_copies, r.physical
        ));
        out.push_str(&format!(
            "      \"bus_channels\": {}, \"batch\": {}, \"pooling\": {},\n",
            p.bus_channels, p.batch, p.pooling
        ));
        for (key, s) in [("reference", &r.reference), ("optimized", &r.optimized)] {
            out.push_str(&format!(
                "      \"{key}\": {{\"sim_queries_per_sec\": {:.1}, \"ns_per_batch\": {:.1}, \
                 \"comparisons_per_batch\": {}}},\n",
                s.qps, s.ns_per_batch, s.comparisons
            ));
        }
        out.push_str(&format!(
            "      \"speedup\": {:.3},\n      \"comparison_ratio\": {:.3}\n",
            r.reference.ns_per_batch / r.optimized.ns_per_batch,
            r.reference.comparisons as f64 / (r.optimized.comparisons.max(1)) as f64
        ));
        out.push_str(if i + 1 == rows.len() { "    }\n" } else { "    },\n" });
    }
    out.push_str("  ],\n");
    out.push_str("  \"reduce\": [\n");
    let kernel = reduce_kernel_name();
    for (i, r) in reduce.iter().enumerate() {
        out.push_str("    {\n");
        out.push_str(&format!(
            "      \"name\": \"{}\", \"dim\": {}, \"rows\": {},\n",
            r.name, r.dim, r.rows
        ));
        out.push_str(&format!(
            "      \"scalar\": {{\"ns_per_reduce\": {:.1}}},\n",
            r.scalar_ns
        ));
        out.push_str(&format!(
            "      \"simd\": {{\"ns_per_reduce\": {:.1}, \"kernel\": \"{kernel}\"}},\n",
            r.simd_ns
        ));
        out.push_str(&format!(
            "      \"par_speedup\": {:.3}\n",
            r.scalar_ns / r.simd_ns
        ));
        out.push_str(if i + 1 == reduce.len() { "    }\n" } else { "    },\n" });
    }
    out.push_str("  ]\n}\n");
    out
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (points, measure_ns) = if smoke {
        (smoke_points(), 60_000_000u64) // 60 ms/side/config: seconds total
    } else {
        (full_points(), 1_000_000_000u64)
    };

    println!(
        "== scheduler throughput: optimized (tree) vs reference (scan), {} mode ==\n",
        if smoke { "smoke" } else { "full" }
    );
    println!(
        "{:<10} {:>7} {:>6} {:>5} {:>6} {:>8} {:>12} {:>12} {:>8} {:>10}",
        "config", "groups", "copies", "bus", "batch", "pooling", "ref q/s", "opt q/s",
        "speedup", "cmp ratio"
    );

    let mut rows = Vec::new();
    for (i, pt) in points.iter().enumerate() {
        let row = run_point(pt, measure_ns, 0xBE11C + i as u64);
        println!(
            "{:<10} {:>7} {:>6} {:>5} {:>6} {:>8} {:>12.0} {:>12.0} {:>7.2}x {:>9.1}x",
            pt.name,
            pt.groups,
            row.max_copies,
            pt.bus_channels,
            pt.batch,
            pt.pooling,
            row.reference.qps,
            row.optimized.qps,
            row.reference.ns_per_batch / row.optimized.ns_per_batch,
            row.reference.comparisons as f64 / row.optimized.comparisons.max(1) as f64,
        );
        rows.push(row);
    }

    println!(
        "\n== reduce kernel: scalar vs {} ==\n",
        reduce_kernel_name()
    );
    println!(
        "{:<12} {:>5} {:>6} {:>12} {:>12} {:>8}",
        "config", "dim", "rows", "scalar ns", "simd ns", "speedup"
    );
    let mut reduce = Vec::new();
    for (i, &(name, dim, nrows)) in reduce_points(smoke).iter().enumerate() {
        let r = run_reduce_point(name, dim, nrows, measure_ns / 4, 0xADD + i as u64);
        println!(
            "{:<12} {:>5} {:>6} {:>12.1} {:>12.1} {:>7.2}x",
            r.name,
            r.dim,
            r.rows,
            r.scalar_ns,
            r.simd_ns,
            r.scalar_ns / r.simd_ns
        );
        reduce.push(r);
    }

    // The perf trajectory lands at the repository root so it diffs and
    // uploads uniformly across PRs regardless of cargo's working dir.
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_sched.json");
    std::fs::write(&path, json(&rows, reduce.as_slice(), smoke)).expect("writing BENCH_sched.json");
    println!("\nwrote {}", path.display());
}
