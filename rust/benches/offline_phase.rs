//! Offline-phase refresh cost: full recompute vs incremental delta, with
//! a machine-readable perf trajectory.
//!
//! Sweeps drift magnitude (what fraction of a window slide comes from a
//! *rotated* popularity distribution) over synthetic Zipf workloads,
//! measures a full offline rebuild (`CoGraph::build` + `Engine::prepare`)
//! against `PreparedEngine::refresh` reacting to the same slide, gates on
//! the identity contract (full-scope refresh bit-identical to a fresh
//! prepare; the window graph bit-identical to a batch rebuild), and
//! writes **`BENCH_offline.json`** at the repository root: per config,
//! ns per rebuild/refresh plus the refresh's work counters (schema v2 in
//! DESIGN.md §"Parallel offline phase & SIMD kernels"). Every side is
//! measured twice — serial (`offline.workers = 1`) and parallel
//! (`offline.workers = 0`, all cores) — after a gate asserting the two
//! widths produce bit-identical mappings and plans; `par_speedup`
//! records what the worker pool buys. CI runs `--smoke` (seconds-scale)
//! on every push, feeds the file through `tools/perf_gate.py`, and
//! uploads it as an artifact, so the trajectory accumulates across PRs.

use recross::config::Config;
use recross::engine::{Engine, PreparedEngine, RefreshReport, Scheme};
use recross::graph::CoGraph;
use recross::util::bench::black_box;
use recross::util::{par, Rng, Zipf};
use recross::workload::{Query, Trace};
use std::time::Instant;

#[derive(Clone, Copy)]
struct SweepPoint {
    name: &'static str,
    embeddings: usize,
    group_size: usize,
    window: usize,
    /// Queries per slide (added == retired, so the window length holds).
    slide: usize,
    /// Percent of each slide drawn from the rotated (drifted) popularity
    /// order; the rest re-samples the base distribution.
    drift_pct: u32,
}

fn pt(
    name: &'static str,
    embeddings: usize,
    group_size: usize,
    window: usize,
    slide: usize,
    drift_pct: u32,
) -> SweepPoint {
    SweepPoint {
        name,
        embeddings,
        group_size,
        window,
        slide,
        drift_pct,
    }
}

fn full_points() -> Vec<SweepPoint> {
    vec![
        pt("drift-2pct", 4096, 32, 2048, 128, 2),
        pt("drift-10pct", 4096, 32, 2048, 128, 10),
        pt("drift-50pct", 4096, 32, 2048, 128, 50),
        pt("big-table", 16384, 64, 4096, 128, 10),
    ]
}

fn smoke_points() -> Vec<SweepPoint> {
    vec![
        pt("drift-2pct", 512, 16, 256, 32, 2),
        pt("drift-10pct", 512, 16, 256, 32, 10),
        pt("drift-50pct", 512, 16, 256, 32, 50),
    ]
}

/// Mean wall-clock ns per call of `f`, with warm-up.
fn measure<F: FnMut()>(mut f: F, measure_ns: u64, min_iters: u64) -> f64 {
    let warm = Instant::now();
    let warm_budget = std::time::Duration::from_nanos(measure_ns / 4);
    let mut warm_iters = 0u64;
    while warm.elapsed() < warm_budget || warm_iters < 1 {
        f();
        warm_iters += 1;
    }
    let start = Instant::now();
    let budget = std::time::Duration::from_nanos(measure_ns);
    let mut iters = 0u64;
    while start.elapsed() < budget || iters < min_iters {
        f();
        iters += 1;
    }
    start.elapsed().as_nanos() as f64 / iters as f64
}

fn zipf_trace(rng: &mut Rng, zipf: &Zipf, perm: &[u32], queries: usize, pooling: usize) -> Trace {
    Trace {
        num_embeddings: perm.len() as u32,
        queries: (0..queries)
            .map(|_| {
                Query::new((0..pooling).map(|_| perm[zipf.sample(rng)]).collect())
            })
            .collect(),
    }
}

/// One slide's worth of added queries: the first `drift_pct` percent
/// from the rotated popularity order, the rest from the base order.
fn slide_batch(
    rng: &mut Rng,
    zipf: &Zipf,
    base: &[u32],
    drifted: &[u32],
    pt: &SweepPoint,
) -> Vec<Query> {
    let n_drift = pt.slide * pt.drift_pct as usize / 100;
    let mut qs = zipf_trace(rng, zipf, drifted, n_drift, 4).queries;
    qs.extend(zipf_trace(rng, zipf, base, pt.slide - n_drift, 4).queries);
    qs
}

struct Row {
    point: SweepPoint,
    /// Serial (1 worker) ns per full rebuild / incremental refresh.
    full_ns: f64,
    inc_ns: f64,
    /// Parallel (all cores) ns per full rebuild / incremental refresh.
    full_par_ns: f64,
    inc_par_ns: f64,
    report: RefreshReport,
}

fn run_point(pt: &SweepPoint, measure_ns: u64, seed: u64) -> Row {
    let n = pt.embeddings;
    let mut cfg = Config::paper_default();
    cfg.scheme.group_size = pt.group_size;
    cfg.scheme.batch_size = 256;
    // Two configs, identical but for the substrate width: serial pins
    // one worker, parallel uses every core (0 = auto).
    let cfg_ser = {
        let mut c = cfg.clone();
        c.offline.workers = 1;
        c
    };
    cfg.offline.workers = 0;

    let mut rng = Rng::new(seed);
    let zipf = Zipf::new(n, 1.05);
    let base: Vec<u32> = (0..n as u32).collect();
    let drifted: Vec<u32> = (0..n as u32).map(|i| (i + n as u32 / 3) % n as u32).collect();
    let window = zipf_trace(&mut rng, &zipf, &base, pt.window, 4);

    // A small cycle of pregenerated slides keeps every measured refresh
    // at the same magnitude without the window drifting unboundedly.
    let slides: Vec<Vec<Query>> = (0..8)
        .map(|_| slide_batch(&mut rng, &zipf, &base, &drifted, pt))
        .collect();

    // Correctness gate: a benchmark of a wrong refresh is worthless.
    // (a) Full-scope refresh is bit-identical to a fresh prepare.
    let mut slid = window.clone();
    slid.queries.drain(..pt.slide);
    slid.queries.extend_from_slice(&slides[0]);
    let mut gate = PreparedEngine::prepare(Scheme::ReCross, &window, &cfg);
    gate.refresh_full(&slides[0], pt.slide);
    let oracle = Engine::prepare(Scheme::ReCross, &CoGraph::build(&slid), &slid, &cfg);
    assert_eq!(
        gate.engine().mapping().groups,
        oracle.mapping().groups,
        "{}: full-scope refresh diverged from fresh prepare",
        pt.name
    );
    assert_eq!(
        gate.engine().replication().copies,
        oracle.replication().copies,
        "{}: full-scope replication diverged from fresh prepare",
        pt.name
    );
    // (b) The incrementally maintained graph equals a batch rebuild.
    let mut pe = PreparedEngine::prepare(Scheme::ReCross, &window, &cfg);
    let report = pe.refresh(&slides[0], pt.slide);
    assert_eq!(
        pe.window_graph().to_cograph(),
        CoGraph::build(&slid),
        "{}: window graph diverged from batch rebuild",
        pt.name
    );
    // (c) Parallel output is bit-identical to serial — a speedup of a
    // wrong answer is worthless. One worker vs all cores, same input.
    par::set_default_workers(1);
    let ser = Engine::prepare(Scheme::ReCross, &CoGraph::build(&slid), &slid, &cfg_ser);
    par::set_default_workers(0);
    let par_e = Engine::prepare(Scheme::ReCross, &CoGraph::build(&slid), &slid, &cfg);
    assert_eq!(
        ser.mapping().groups,
        par_e.mapping().groups,
        "{}: parallel grouping diverged from serial",
        pt.name
    );
    assert_eq!(
        ser.replication().copies,
        par_e.replication().copies,
        "{}: parallel replication diverged from serial",
        pt.name
    );

    // Incremental side, serial then parallel: one slide per iteration,
    // cycling the batch pool. Each PreparedEngine::prepare threads its
    // config's worker count into the substrate.
    let mut pe_ser = PreparedEngine::prepare(Scheme::ReCross, &window, &cfg_ser);
    let mut i = 0usize;
    let inc_ns = measure(
        || {
            black_box(pe_ser.refresh(&slides[i % slides.len()], pt.slide));
            i += 1;
        },
        measure_ns,
        2,
    );
    let mut pe_par = PreparedEngine::prepare(Scheme::ReCross, &window, &cfg);
    let mut i = 0usize;
    let inc_par_ns = measure(
        || {
            black_box(pe_par.refresh(&slides[i % slides.len()], pt.slide));
            i += 1;
        },
        measure_ns,
        2,
    );

    // Full side: the O(table) recompute the refresh replaces — rebuild
    // the affinity graph and re-run the whole offline pipeline over the
    // same (slid) window. Serial first, then all cores.
    par::set_default_workers(1);
    let full_ns = measure(
        || {
            black_box(Engine::prepare(
                Scheme::ReCross,
                &CoGraph::build(&slid),
                &slid,
                &cfg_ser,
            ));
        },
        measure_ns,
        2,
    );
    par::set_default_workers(0);
    let full_par_ns = measure(
        || {
            black_box(Engine::prepare(
                Scheme::ReCross,
                &CoGraph::build(&slid),
                &slid,
                &cfg,
            ));
        },
        measure_ns,
        2,
    );

    Row {
        point: *pt,
        full_ns,
        inc_ns,
        full_par_ns,
        inc_par_ns,
        report,
    }
}

fn json(rows: &[Row], smoke: bool, workers: usize) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"bench\": \"offline_phase\",\n");
    out.push_str("  \"version\": 2,\n");
    out.push_str(&format!("  \"mode\": \"{}\",\n", if smoke { "smoke" } else { "full" }));
    out.push_str(&format!("  \"workers\": {workers},\n"));
    out.push_str("  \"configs\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let p = &r.point;
        out.push_str("    {\n");
        out.push_str(&format!("      \"name\": \"{}\",\n", p.name));
        out.push_str(&format!(
            "      \"embeddings\": {}, \"group_size\": {}, \"window_queries\": {}, \
             \"slide_queries\": {}, \"drift_pct\": {},\n",
            p.embeddings, p.group_size, p.window, p.slide, p.drift_pct
        ));
        out.push_str(&format!(
            "      \"full\": {{\"ns_per_rebuild\": {:.1}, \"rebuilds_per_sec\": {:.2}}},\n",
            r.full_ns,
            1e9 / r.full_ns
        ));
        out.push_str(&format!(
            "      \"full_parallel\": {{\"ns_per_rebuild\": {:.1}, \"rebuilds_per_sec\": {:.2}}},\n",
            r.full_par_ns,
            1e9 / r.full_par_ns
        ));
        out.push_str(&format!(
            "      \"incremental\": {{\"ns_per_refresh\": {:.1}, \"refreshes_per_sec\": {:.2}, \
             \"dirty_nodes\": {}, \"groups_changed\": {}, \"groups_total\": {}, \
             \"ids_moved\": {}, \"ids_total\": {}}},\n",
            r.inc_ns,
            1e9 / r.inc_ns,
            r.report.dirty_nodes,
            r.report.groups_changed,
            r.report.groups_total,
            r.report.ids_moved,
            r.report.ids_total
        ));
        out.push_str(&format!(
            "      \"incremental_parallel\": {{\"ns_per_refresh\": {:.1}, \
             \"refreshes_per_sec\": {:.2}}},\n",
            r.inc_par_ns,
            1e9 / r.inc_par_ns
        ));
        out.push_str(&format!(
            "      \"speedup\": {:.3},\n",
            r.full_ns / r.inc_ns
        ));
        out.push_str(&format!(
            "      \"par_speedup\": {:.3},\n",
            r.full_ns / r.full_par_ns
        ));
        out.push_str(&format!(
            "      \"par_speedup_refresh\": {:.3}\n",
            r.inc_ns / r.inc_par_ns
        ));
        out.push_str(if i + 1 == rows.len() { "    }\n" } else { "    },\n" });
    }
    out.push_str("  ]\n}\n");
    out
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (points, measure_ns) = if smoke {
        (smoke_points(), 60_000_000u64) // 60 ms/side/config: seconds total
    } else {
        (full_points(), 1_000_000_000u64)
    };

    // Effective all-cores worker count, reported in the JSON header.
    par::set_default_workers(0);
    let workers = par::default_workers();
    println!(
        "== offline phase: full rebuild vs incremental refresh, {} mode, {} workers ==\n",
        if smoke { "smoke" } else { "full" },
        workers
    );
    println!(
        "{:<12} {:>8} {:>7} {:>6} {:>12} {:>12} {:>8} {:>8} {:>8}",
        "config", "embeds", "window", "drift", "rebuild ns", "refresh ns", "speedup", "par-full",
        "par-inc"
    );

    let mut rows = Vec::new();
    for (i, pt) in points.iter().enumerate() {
        let row = run_point(pt, measure_ns, 0x0FF1_1E + i as u64);
        println!(
            "{:<12} {:>8} {:>7} {:>5}% {:>12.0} {:>12.0} {:>7.2}x {:>7.2}x {:>7.2}x",
            pt.name,
            pt.embeddings,
            pt.window,
            pt.drift_pct,
            row.full_ns,
            row.inc_ns,
            row.full_ns / row.inc_ns,
            row.full_ns / row.full_par_ns,
            row.inc_ns / row.inc_par_ns,
        );
        rows.push(row);
    }

    // The perf trajectory lands at the repository root so it diffs and
    // uploads uniformly across PRs regardless of cargo's working dir.
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_offline.json");
    std::fs::write(&path, json(&rows, smoke, workers)).expect("writing BENCH_offline.json");
    println!("\nwrote {}", path.display());
}
