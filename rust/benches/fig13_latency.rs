//! Fig. 13 (extension): open-loop offered load → tail latency.
//!
//! The paper's Fig. 8 compares batch completion times; a serving system
//! cares where the **hockey stick** sits: as offered load approaches the
//! pool's service capacity, queueing delay — and p99 — diverges. This
//! bench drives identical Poisson traffic through the open-loop driver
//! ([`recross::loadgen`]) for the naive and ReCross mappings and for
//! 1..N shards, and reports the load each configuration sustains before
//! its tail blows past a 10× service-time SLO.
//!
//! `--smoke` runs a seconds-scale configuration for CI.

use recross::cluster::{PoolShared, ShardPlan};
use recross::config::Config;
use recross::coordinator::BatchPolicy;
use recross::deploy::SimBackend;
use recross::engine::{Engine, Scheme};
use recross::graph::CoGraph;
use recross::loadgen::{drive, Arrivals, OpenLoopReport};
use recross::util::fmt_ns;
use recross::workload::{DatasetSpec, Generator, Trace};
use std::time::Duration;

/// SLO multiple over the near-zero-load p99 that counts as "sustained".
const SLO_FACTOR: f64 = 10.0;

fn drive_engine(
    engine: &Engine,
    trace: &Trace,
    arrivals: &[u64],
    policy: &BatchPolicy,
) -> OpenLoopReport {
    drive(&SimBackend::of_engine(engine), &trace.queries, arrivals, policy)
}

/// Closed-loop capacity proxy: queries per second of pure serial service
/// (batch completions accumulate across a trace).
fn capacity_qps(engine: &Engine, trace: &Trace, batch: usize) -> f64 {
    let stats = engine.run_trace(trace, batch);
    trace.queries.len() as f64 / (stats.completion_ns / 1e9)
}

fn geometric_sweep(lo: f64, hi: f64, points: usize) -> Vec<f64> {
    let ratio = (hi / lo).powf(1.0 / (points as f64 - 1.0));
    (0..points).map(|i| lo * ratio.powi(i as i32)).collect()
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let (scale, history_n, num_queries, points, shard_set): (f64, usize, usize, usize, &[usize]) =
        if smoke {
            (0.02, 400, 256, 5, &[1, 2])
        } else {
            (0.1, 3_000, 4_096, 9, &[1, 2, 4, 8])
        };
    let spec = DatasetSpec::by_name("software").unwrap().scaled(scale);
    let gen = Generator::new(&spec, 42);
    let history = gen.trace(history_n, 43);
    let trace = gen.trace(num_queries, 44);
    let graph = CoGraph::build(&history);
    let cfg = Config::paper_default();
    let policy = BatchPolicy {
        max_batch: 32,
        max_wait: Duration::from_micros(5),
    };

    let naive = Engine::prepare(Scheme::Naive, &graph, &history, &cfg);
    let recross = Engine::prepare(Scheme::ReCross, &graph, &history, &cfg);
    let cap_naive = capacity_qps(&naive, &trace, policy.max_batch);
    let cap_re = capacity_qps(&recross, &trace, policy.max_batch);
    println!(
        "== fig13: offered load -> p99 sojourn (software@{scale}, {num_queries} queries, \
         batch<=32, wait 5µs) ==\n"
    );
    println!(
        "closed-loop capacity estimate: naive {:.0} q/s, recross {:.0} q/s\n",
        cap_naive, cap_re
    );

    // --- naive vs ReCross mapping, single pool ---------------------------
    let rates = geometric_sweep(0.2 * cap_naive, 2.0 * cap_re.max(cap_naive), points);
    // Near-zero-load baseline p99 = pure service time (the SLO anchor).
    let idle = Arrivals::poisson(0.05 * cap_naive, 7).take(num_queries);
    let base_naive = drive_engine(&naive, &trace, &idle, &policy).percentile_ns(99.0);
    let base_re = drive_engine(&recross, &trace, &idle, &policy).percentile_ns(99.0);

    println!(
        "{:>12} {:>14} {:>14} {:>14} {:>14}",
        "rate q/s", "naive p50", "naive p99", "recross p50", "recross p99"
    );
    // Highest rate meeting the SLO *before the first violation*: a
    // later dip back under the SLO (nearest-rank noise near the knee)
    // must not resurrect a configuration that already broke.
    let mut sustained = [0.0f64; 2]; // [naive, recross]
    let mut broken = [false; 2];
    for &rate in &rates {
        let arrivals = Arrivals::poisson(rate, 7).take(num_queries);
        let rn = drive_engine(&naive, &trace, &arrivals, &policy);
        let rr = drive_engine(&recross, &trace, &arrivals, &policy);
        for (i, (r, base)) in [(&rn, base_naive), (&rr, base_re)].iter().enumerate() {
            if r.percentile_ns(99.0) <= SLO_FACTOR * base {
                if !broken[i] {
                    sustained[i] = rate;
                }
            } else {
                broken[i] = true;
            }
        }
        println!(
            "{:>12.0} {:>14} {:>14} {:>14} {:>14}",
            rate,
            fmt_ns(rn.percentile_ns(50.0)),
            fmt_ns(rn.percentile_ns(99.0)),
            fmt_ns(rr.percentile_ns(50.0)),
            fmt_ns(rr.percentile_ns(99.0)),
        );
    }
    println!(
        "\nsustained load (p99 <= {SLO_FACTOR}x idle p99): naive {:.0} q/s, recross {:.0} q/s \
         ({:.2}x)",
        sustained[0],
        sustained[1],
        sustained[1] / sustained[0].max(1e-9)
    );
    if sustained[1] <= sustained[0] {
        println!("WARNING: recross did not sustain more load than naive on this sweep");
    }

    // --- shard scaling under the ReCross mapping -------------------------
    println!("\n== fig13b: p99 vs offered load, 1..N shards (recross mapping) ==\n");
    let shared = PoolShared::from_engine(&recross);
    print!("{:>12}", "rate q/s");
    for &s in shard_set {
        print!(" {:>13}", format!("p99 x{s}"));
    }
    println!();
    let shard_rates = geometric_sweep(
        0.5 * cap_re,
        2.0 * cap_re * *shard_set.last().unwrap() as f64,
        points,
    );
    let backends: Vec<SimBackend> = shard_set
        .iter()
        .map(|&s| {
            SimBackend::sharded(
                &shared,
                ShardPlan::by_locality(&shared.mapping, &history, s, 0.10),
            )
        })
        .collect();
    for &rate in &shard_rates {
        let arrivals = Arrivals::poisson(rate, 7).take(num_queries);
        print!("{rate:>12.0}");
        for backend in &backends {
            let r = drive(backend, &trace.queries, &arrivals, &policy);
            print!(" {:>13}", fmt_ns(r.percentile_ns(99.0)));
        }
        println!();
    }
    println!(
        "\n(diverging columns mark each pool's saturation point; more shards \
         push the hockey stick right)"
    );
}
