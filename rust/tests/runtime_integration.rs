//! Integration tests over the PJRT runtime + coordinator pipeline.
//!
//! These need `make artifacts`; when artifacts are missing they skip with a
//! message instead of failing, so `cargo test` stays meaningful in a fresh
//! checkout.

use recross::config::Config;
use recross::coordinator::{self, BatchPolicy, Request, Server};
use recross::engine::Scheme;
use recross::runtime::{artifacts_available, DlrmParams, Runtime};
use recross::util::Rng;
use recross::workload::Query;

const ARTIFACTS: &str = "artifacts";

macro_rules! require_artifacts {
    () => {
        if !artifacts_available(ARTIFACTS) {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
    };
}

fn small_cfg() -> Config {
    let mut cfg = Config::paper_default();
    cfg.workload.history_queries = 300;
    cfg.workload.eval_queries = 60;
    cfg.workload.dataset = "software".into();
    cfg
}

#[test]
fn runtime_loads_and_reports_platform() {
    require_artifacts!();
    let rt = Runtime::load(ARTIFACTS).unwrap();
    assert!(rt.platform().to_lowercase().contains("cpu") || !rt.platform().is_empty());
    assert_eq!(rt.manifest().embed_dim, 16);
    assert_eq!(rt.manifest().xbar_rows, 64);
}

#[test]
fn reduce_artifact_matches_manual_sum() {
    require_artifacts!();
    let rt = Runtime::load(ARTIFACTS).unwrap();
    let m = rt.manifest().clone();
    let mut rng = Rng::new(7);
    // Random tiles, a few random mask bits.
    let tiles: Vec<f32> = (0..m.tiles * m.xbar_rows * m.embed_dim)
        .map(|_| (rng.normal() * 0.1) as f32)
        .collect();
    let mut masks = vec![0.0f32; m.tiles * m.xbar_rows];
    let mut expect = vec![0.0f32; m.embed_dim];
    for _ in 0..10 {
        let t = rng.index(m.tiles);
        let r = rng.index(m.xbar_rows);
        if masks[t * m.xbar_rows + r] == 1.0 {
            continue;
        }
        masks[t * m.xbar_rows + r] = 1.0;
        for d in 0..m.embed_dim {
            expect[d] += tiles[(t * m.xbar_rows + r) * m.embed_dim + d];
        }
    }
    let got = rt.reduce(1, &masks, &tiles).unwrap();
    assert_eq!(got.len(), m.embed_dim);
    for (g, e) in got.iter().zip(&expect) {
        assert!((g - e).abs() < 1e-4, "{g} vs {e}");
    }
}

#[test]
fn dlrm_head_composes_with_reduce() {
    // dlrm_b* (fused) must equal reduce_b* + dlrm_head_b* on the same
    // inputs: the serving-path split is semantics-preserving.
    require_artifacts!();
    let rt = Runtime::load(ARTIFACTS).unwrap();
    let m = rt.manifest().clone();
    let params = DlrmParams::init(&m, 99);
    let mut rng = Rng::new(3);
    let b = 1;
    let dense: Vec<f32> = (0..b * m.dense_features).map(|_| rng.normal() as f32).collect();
    let tiles: Vec<f32> = (0..m.tiles * m.xbar_rows * m.embed_dim)
        .map(|_| (rng.normal() * 0.1) as f32)
        .collect();
    let mut masks = vec![0.0f32; b * m.tiles * m.xbar_rows];
    let mask_len = masks.len();
    for i in 0..8 {
        masks[i * 13 % mask_len] = 1.0;
    }
    let fused = rt.dlrm_forward(b, &dense, &masks, &tiles, &params).unwrap();
    let reduced = rt.reduce(b, &masks, &tiles).unwrap();
    let split = rt.dlrm_head(b, &dense, &reduced, &params).unwrap();
    assert_eq!(fused.len(), split.len());
    for (f, s) in fused.iter().zip(&split) {
        assert!((f - s).abs() < 1e-4, "fused {f} vs split {s}");
    }
}

#[test]
fn pipeline_reduction_matches_reference() {
    // End-to-end: the coordinator's chunked crossbar reduction through
    // PJRT equals the plain master-table sum, for recross AND naive
    // mappings (layout-independence).
    require_artifacts!();
    let cfg = small_cfg();
    for scheme in [Scheme::ReCross, Scheme::Naive] {
        let mut pipeline = coordinator::build_pipeline(&cfg, scheme, 0.02).unwrap();
        let mut rng = Rng::new(11);
        for _ in 0..5 {
            let n_items = rng.range(1, 40) as usize;
            let max = pipeline.store().num_groups() as u32 * 32;
            let items: Vec<u32> = (0..n_items)
                .map(|_| rng.below(max.min(500) as u64) as u32)
                .collect();
            let q = Query::new(items);
            let got = pipeline.reduce_query(&q).unwrap();
            let expect = pipeline.store().reduce_reference(&q.items);
            for (g, e) in got.iter().zip(&expect) {
                assert!(
                    (g - e).abs() < 1e-3,
                    "{:?}: {g} vs {e}",
                    scheme
                );
            }
        }
    }
}

#[test]
fn server_batches_and_answers() {
    require_artifacts!();
    let cfg = small_cfg();
    let policy = BatchPolicy {
        max_batch: 8,
        max_wait: std::time::Duration::from_millis(1),
    };
    let cfg2 = cfg.clone();
    let server = Server::spawn(policy, move || {
        coordinator::build_pipeline(&cfg2, Scheme::ReCross, 0.02)
    })
    .unwrap();
    let handle = server.handle();

    let mut rng = Rng::new(21);
    let reqs: Vec<Request> = (0..20)
        .map(|id| Request {
            id,
            dense: (0..13).map(|_| rng.normal() as f32).collect(),
            items: (0..10).map(|_| rng.below(400) as u32).collect(),
        })
        .collect();
    let responses = handle.infer_many(reqs.clone()).unwrap();
    assert_eq!(responses.len(), 20);
    for (resp, req) in responses.iter().zip(&reqs) {
        assert_eq!(resp.id, req.id);
        assert!(resp.logit.is_finite());
        assert!(resp.activations > 0);
        assert_eq!(resp.reduced.len(), 16);
    }
    // Same request twice -> identical logits (deterministic pipeline).
    let r1 = handle.infer(reqs[0].clone()).unwrap();
    let r2 = handle.infer(reqs[0].clone()).unwrap();
    assert_eq!(r1.logit, r2.logit);
}

#[test]
fn pipeline_drift_monitor_tracks_traffic() {
    require_artifacts!();
    let cfg = small_cfg();
    let mut pipeline = coordinator::build_pipeline(&cfg, Scheme::ReCross, 0.02).unwrap();
    // Baseline from the engine's own validation-style stats.
    pipeline.set_drift_baseline(0.2);
    assert!(pipeline.drift().current().is_none());
    let reqs: Vec<Request> = (0..4)
        .map(|id| Request {
            id,
            dense: vec![0.1; 13],
            items: vec![1, 2, 3, 4, 5, 6, 7, 8],
        })
        .collect();
    let since = vec![std::time::Instant::now(); 4];
    pipeline.infer_batch(&reqs, &since).unwrap();
    // Monitor observed the batch.
    assert!(pipeline.drift().current().is_some());
    assert!(pipeline.drift().degradation() > 0.0);
}

#[test]
fn server_survives_bad_request() {
    require_artifacts!();
    let cfg = small_cfg();
    let cfg2 = cfg.clone();
    let server = Server::spawn(BatchPolicy::default(), move || {
        coordinator::build_pipeline(&cfg2, Scheme::ReCross, 0.02)
    })
    .unwrap();
    let handle = server.handle();
    // Wrong dense width -> error response, not a dead server.
    let bad = Request {
        id: 1,
        dense: vec![0.0; 3],
        items: vec![1, 2],
    };
    assert!(handle.infer(bad).is_err());
    // Server still serves good requests afterwards.
    let good = Request {
        id: 2,
        dense: vec![0.1; 13],
        items: vec![1, 2, 3],
    };
    assert!(handle.infer(good).is_ok());
}
