//! System-level integration tests: the offline→online pipeline on all five
//! calibrated datasets (tiny scale), cross-checking the paper's ordering
//! claims without requiring PJRT artifacts.

use recross::config::Config;
use recross::engine::{Engine, Scheme};
use recross::graph::CoGraph;
use recross::metrics::fit_power_law;
use recross::report::{self, Workbench};
use recross::workload::{access_frequencies, generate, DatasetSpec};

const SCALE: f64 = 0.02;

fn prepared(name: &str) -> (CoGraph, recross::workload::Trace, recross::workload::Trace, Config) {
    let spec = DatasetSpec::by_name(name).unwrap().scaled(SCALE);
    let (history, eval) = generate(&spec, 1_200, 400, 42);
    let graph = CoGraph::build(&history);
    (graph, history, eval, Config::paper_default())
}

#[test]
fn all_datasets_power_law_access() {
    // Fig. 2 premise on every calibrated dataset.
    for name in DatasetSpec::names() {
        let (_, history, _, _) = prepared(name);
        let fit = fit_power_law(&access_frequencies(&history)).unwrap();
        assert!(
            fit.is_power_law(),
            "{name}: access distribution not power-law ({fit:?})"
        );
    }
}

#[test]
fn recross_wins_activations_on_every_dataset() {
    // Fig. 9 ordering: recross < frequency <= naive, everywhere.
    for name in DatasetSpec::names() {
        let (graph, history, eval, cfg) = prepared(name);
        let naive = Engine::prepare(Scheme::Naive, &graph, &history, &cfg).count_activations(&eval);
        let freq =
            Engine::prepare(Scheme::Frequency, &graph, &history, &cfg).count_activations(&eval);
        let re = Engine::prepare(Scheme::ReCross, &graph, &history, &cfg).count_activations(&eval);
        assert!(re < freq, "{name}: recross {re} !< frequency {freq}");
        assert!(freq <= naive, "{name}: frequency {freq} !<= naive {naive}");
    }
}

#[test]
fn recross_wins_time_and_energy_on_every_dataset() {
    // Fig. 8 ordering at tiny scale: ReCross beats naive and nMARS on both
    // completion time and energy.
    for name in DatasetSpec::names() {
        let (graph, history, eval, cfg) = prepared(name);
        let bs = cfg.scheme.batch_size;
        let nv = Engine::prepare(Scheme::Naive, &graph, &history, &cfg).run_trace(&eval, bs);
        let nm = Engine::prepare(Scheme::Nmars, &graph, &history, &cfg).run_trace(&eval, bs);
        let re = Engine::prepare(Scheme::ReCross, &graph, &history, &cfg).run_trace(&eval, bs);
        assert!(
            re.completion_ns < nv.completion_ns,
            "{name}: time vs naive ({} vs {})",
            re.completion_ns,
            nv.completion_ns
        );
        assert!(
            re.completion_ns < nm.completion_ns,
            "{name}: time vs nmars"
        );
        assert!(re.energy_pj < nv.energy_pj, "{name}: energy vs naive");
        assert!(re.energy_pj < nm.energy_pj, "{name}: energy vs nmars");
    }
}

#[test]
fn fig10_duplication_converges() {
    // More area -> completion never degrades, and the marginal gain
    // shrinks (the paper's convergence claim).
    let mut wb = Workbench::new(SCALE, 1_200, 400, 64, 42);
    let sweep = wb.dup_sweep("automotive", &[0.0, 0.05, 0.10, 0.20]);
    for w in sweep.windows(2) {
        assert!(
            w[1].completion_ns <= w[0].completion_ns * 1.001,
            "more duplication should not hurt: {} -> {}",
            w[0].completion_ns,
            w[1].completion_ns
        );
    }
    let gain_first = sweep[0].completion_ns / sweep[1].completion_ns;
    let gain_last = sweep[2].completion_ns / sweep[3].completion_ns;
    assert!(
        gain_last <= gain_first + 1e-9,
        "gain should shrink: first {gain_first}, last {gain_last}"
    );
}

#[test]
fn fig11_host_platforms_orders_of_magnitude_worse() {
    let mut wb = Workbench::new(SCALE, 1_200, 400, 64, 42);
    let out = report::fig11(&mut wb);
    // At least two orders of magnitude, per the paper's abstract.
    let avg_line = out.lines().find(|l| l.contains("AVERAGE")).unwrap();
    let nums: Vec<f64> = avg_line
        .split_whitespace()
        .filter_map(|t| t.trim_end_matches('x').parse().ok())
        .collect();
    assert_eq!(nums.len(), 2, "line: {avg_line}");
    assert!(nums[0] > 100.0, "vs CPU only {}", nums[0]);
    assert!(nums[1] > nums[0], "CPU+GPU should be worse than CPU");
}

#[test]
fn offline_phase_deterministic() {
    for name in ["software", "sports"] {
        let (graph, history, eval, cfg) = prepared(name);
        let a = Engine::prepare(Scheme::ReCross, &graph, &history, &cfg);
        let b = Engine::prepare(Scheme::ReCross, &graph, &history, &cfg);
        assert_eq!(a.mapping().groups, b.mapping().groups, "{name}");
        assert_eq!(a.replication().copies, b.replication().copies, "{name}");
        let sa = a.run_trace(&eval, 64);
        let sb = b.run_trace(&eval, 64);
        assert_eq!(sa, sb, "{name}: whole pipeline must be deterministic");
    }
}

#[test]
fn single_row_share_tracks_dataset_tail() {
    // Fig. 6: automotive (heavy uncorrelated tail) must have a higher
    // single-embedding share than software (light tail).
    let (g_sw, h_sw, e_sw, cfg) = prepared("software");
    let (g_au, h_au, e_au, _) = prepared("automotive");
    let sw = Engine::prepare(Scheme::ReCross, &g_sw, &h_sw, &cfg).run_trace(&e_sw, 256);
    let au = Engine::prepare(Scheme::ReCross, &g_au, &h_au, &cfg).run_trace(&e_au, 256);
    assert!(
        au.single_row_share() > sw.single_row_share(),
        "automotive {:.2} should exceed software {:.2}",
        au.single_row_share(),
        sw.single_row_share()
    );
}

#[test]
fn report_all_runs_end_to_end() {
    // The full report harness must execute without panicking and mention
    // every figure.
    let mut wb = Workbench::new(0.01, 400, 128, 64, 7);
    let out = report::all(&mut wb);
    for key in ["TABLE I", "FIG 2", "FIG 4", "FIG 5", "FIG 6", "FIG 8", "FIG 9", "FIG 10", "FIG 11"] {
        assert!(out.contains(key), "missing {key}");
    }
}
