//! Differential fuzz: the data-oriented scheduler must be **bit-identical**
//! to the preserved naive loop (`sched::reference`).
//!
//! The optimized hot path (tournament-tree slot selection, sort-free run
//! decomposition) is only admissible because it provably changes nothing:
//! same replica picked, same bus channel picked, same float arithmetic in
//! the same order. This suite runs ≥200 seeded random configurations —
//! replication (including copies ≫ batch), single-channel buses, tree-
//! and flat-mode tables, cold-start floods past the catalogue, empty
//! queries, nMARS, and the timed path — and requires exact `ExecStats`
//! and per-query `finish_ns` equality (`==` on `f64`, not tolerance).
//!
//! It also pins the *point* of the rewrite: on a high-replication,
//! wide-bus config the tree scheduler performs asymptotically fewer slot
//! comparisons than the reference scan (counters threaded through
//! `minslot` / `ReferenceScratch`).

use recross::allocation::Replication;
use recross::config::HardwareConfig;
use recross::grouping::Mapping;
use recross::sched::{ReferenceScheduler, ReferenceScratch, Scheduler, Scratch};
use recross::util::Rng;
use recross::workload::Query;
use recross::xbar::{CircuitParams, CrossbarModel};

/// A random catalogue mapping: shuffled ids, a random prefix placed into
/// random-sized groups, the rest left to cold-start overflow packing.
fn random_mapping(rng: &mut Rng) -> Mapping {
    let group_size = rng.range(1, 12) as usize;
    let n = rng.range(4, 300) as usize;
    let mut ids: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut ids);
    let placed = rng.range(0, n as u64) as usize;
    let mut groups: Vec<Vec<u32>> = Vec::new();
    let mut i = 0;
    while i < placed {
        let take = (rng.range(1, group_size as u64) as usize).min(placed - i);
        groups.push(ids[i..i + take].to_vec());
        i += take;
    }
    Mapping::from_groups(groups, group_size, n)
}

/// Random replication: mostly light (Eq. 1-ish), occasionally one group
/// heavily replicated so the busy table crosses into tree mode.
fn random_replication(rng: &mut Rng, num_groups: usize) -> Replication {
    let copies: Vec<u32> = (0..num_groups)
        .map(|_| {
            if rng.chance(0.08) {
                rng.range(2, 60) as u32
            } else {
                rng.range(1, 6) as u32
            }
        })
        .collect();
    Replication::from_copies(copies, 256)
}

/// Random query batch over `n` in-catalogue ids plus a cold-start tail
/// of ids the offline phase never saw.
fn random_queries(rng: &mut Rng, n: usize) -> Vec<Query> {
    let nq = rng.range(0, 40) as usize;
    (0..nq)
        .map(|_| {
            if rng.chance(0.05) {
                return Query::new(Vec::new());
            }
            if rng.chance(0.05) {
                // Cold-start flood: distinct out-of-catalogue ids.
                let start = n as u32 + rng.below(50) as u32;
                return Query::new((start..start + rng.range(1, 20) as u32).collect());
            }
            let k = rng.range(0, 30) as usize;
            let hi = (n + n / 2 + 1) as u64; // ~1/3 of draws past the catalogue
            Query::new((0..k).map(|_| rng.below(hi) as u32).collect())
        })
        .collect()
}

/// Scratch pair shared across every checked configuration — table
/// resizing, epoch stamping, and flat<->tree layout flips are part of
/// what is under test.
#[derive(Default)]
struct Scratches {
    opt: Scratch,
    naive: ReferenceScratch,
}

/// Assert all three entry points agree exactly for one configuration.
fn assert_equivalent(
    map: &Mapping,
    rep: &Replication,
    model: &CrossbarModel,
    dynamic_switch: bool,
    queries: &[Query],
    s: &mut Scratches,
    label: &str,
) {
    let opt = Scheduler::new(map, rep, model, dynamic_switch);
    let naive = ReferenceScheduler::new(map, rep, model, dynamic_switch);

    let a = opt.run_batch(queries, &mut s.opt);
    let b = naive.run_batch(queries, &mut s.naive);
    assert_eq!(a, b, "[{label}] run_batch diverged");

    let (mut fa, mut fb) = (Vec::new(), Vec::new());
    let ta = opt.run_batch_timed(queries, &mut s.opt, &mut fa);
    let tb = naive.run_batch_timed(queries, &mut s.naive, &mut fb);
    assert_eq!(ta, tb, "[{label}] run_batch_timed stats diverged");
    assert_eq!(fa, fb, "[{label}] per-query finish_ns diverged");
    assert_eq!(ta, a, "[{label}] timing perturbed the schedule");

    let na = opt.run_batch_nmars(queries, &mut s.opt);
    let nb = naive.run_batch_nmars(queries, &mut s.naive);
    assert_eq!(na, nb, "[{label}] run_batch_nmars diverged");
}

#[test]
fn fuzz_bit_identical_across_random_configs() {
    let mut scratches = Scratches::default();
    let params = CircuitParams::default();
    for seed in 0..220u64 {
        let mut rng = Rng::new(0x5EED_0000 + seed);
        let map = random_mapping(&mut rng);
        let rep = random_replication(&mut rng, map.num_groups());
        let hw = HardwareConfig {
            bus_channels: rng.range(1, 40) as usize,
            ..Default::default()
        };
        let model = CrossbarModel::new(&hw, &params);
        let dynamic_switch = rng.chance(0.5);
        let queries = random_queries(&mut rng, map.num_embeddings());
        assert_equivalent(
            &map,
            &rep,
            &model,
            dynamic_switch,
            &queries,
            &mut scratches,
            &format!("seed {seed}"),
        );
    }
}

#[test]
fn directed_edge_configs_bit_identical() {
    let params = CircuitParams::default();
    let mut scratches = Scratches::default();

    let groups: Vec<Vec<u32>> = (0..16u32).map(|g| (4 * g..4 * g + 4).collect()).collect();
    let map = Mapping::from_groups(groups, 4, 64);

    // copies = 1 everywhere (no replica selection at all).
    let identity = Replication::identity(16, 256);
    // copies >> batch: 64 copies per group, 2-query batches.
    let heavy = Replication::from_copies(vec![64; 16], 2);

    let mut rng = Rng::new(0xD1CE);
    let small_batch: Vec<Query> = (0..2)
        .map(|_| Query::new((0..8).map(|_| rng.below(64) as u32).collect()))
        .collect();
    let batch: Vec<Query> = (0..48)
        .map(|_| Query::new((0..12).map(|_| rng.below(96) as u32).collect()))
        .collect();
    let flood: Vec<Query> = (0..8)
        .map(|i| Query::new((64 + 32 * i..64 + 32 * i + 24).collect()))
        .collect();
    let empties = vec![Query::new(vec![]), Query::new(vec![]), Query::new(vec![])];

    for &bus in &[1usize, 2, 16, 33, 128] {
        let hw = HardwareConfig {
            bus_channels: bus,
            ..Default::default()
        };
        let model = CrossbarModel::new(&hw, &params);
        for (rep, qs, label) in [
            (&identity, &batch, "identity"),
            (&identity, &flood, "identity+cold-flood"),
            (&identity, &empties, "identity+all-empty"),
            (&heavy, &small_batch, "copies>>batch"),
            (&heavy, &batch, "heavy"),
        ] {
            assert_equivalent(
                &map,
                rep,
                &model,
                true,
                qs,
                &mut scratches,
                &format!("{label}, bus={bus}"),
            );
        }
    }

    // Empty batch entirely.
    let model = CrossbarModel::new(&HardwareConfig::default(), &params);
    assert_equivalent(&map, &identity, &model, true, &[], &mut scratches, "empty batch");
}

#[test]
fn tree_scheduler_does_asymptotically_fewer_comparisons() {
    // High replication, wide bus: 64 groups x 256 copies and 256 bus
    // channels. Per activation the reference scans 255 replica slots +
    // 255 channels; the tree pays ~2 log2(256) query visits plus a
    // log2(16384) root path per update, and reads the bus minimum off
    // the root for free. The counters must show a multiple-x gap — this
    // is the asymptotic win, pinned as a test so a future "cleanup" that
    // quietly reverts to scans fails loudly.
    let groups: Vec<Vec<u32>> = (0..64u32).map(|g| (4 * g..4 * g + 4).collect()).collect();
    let map = Mapping::from_groups(groups, 4, 256);
    let rep = Replication::from_copies(vec![256; 64], 256);
    let hw = HardwareConfig {
        bus_channels: 256,
        ..Default::default()
    };
    let model = CrossbarModel::new(&hw, &CircuitParams::default());
    let opt = Scheduler::new(&map, &rep, &model, true);
    let naive = ReferenceScheduler::new(&map, &rep, &model, true);

    let mut rng = Rng::new(0xC0DE);
    let queries: Vec<Query> = (0..256)
        .map(|_| Query::new((0..8).map(|_| rng.below(256) as u32).collect()))
        .collect();

    let mut scratch = Scratch::default();
    let mut rscratch = ReferenceScratch::default();
    scratch.reset_comparisons();
    rscratch.reset_comparisons();
    let a = opt.run_batch(&queries, &mut scratch);
    let b = naive.run_batch(&queries, &mut rscratch);
    assert_eq!(a, b, "schedules must still be identical");
    assert!(a.activations > 500, "workload too small to be meaningful");

    let tree = scratch.comparisons();
    let scan = rscratch.comparisons();
    assert!(
        tree * 4 < scan,
        "tree comparisons {tree} not asymptotically below scan {scan}"
    );
    // Sanity on the scan side: exactly (copies-1) + (channels-1) = 510
    // comparisons per activation.
    assert_eq!(scan, a.activations * 510);
}
