//! Builder-built stacks must be **bit-identical** to the legacy
//! hand-assembled wiring, for all four schemes × {single, sharded-pinned,
//! sharded-routed}:
//!
//! * exact `ExecStats` equality between the `deploy`-built engine /
//!   simulators and the legacy `OfflinePhase` + `simulate_*` paths,
//! * exact `OpenLoopReport` equality between the unified
//!   `loadgen::drive` and the deprecated `drive_single`/`drive_sharded`
//!   shims,
//! * exact (not approximate) reduction equality on integer-valued
//!   tables, where float summation order cannot hide a routing bug.

use recross::cluster::{
    simulate_sharded, simulate_with_replicas, ClusterConfig, PoolShared, ReplicaPlan,
    RoutePolicy, ShardPlan, ShardingMode,
};
use recross::config::Config;
use recross::coordinator::{BatchPolicy, EmbeddingStore, OfflinePhase};
use recross::deploy::{Backend, Deployment, Prepared, Sharded};
use recross::engine::Scheme;
use recross::loadgen::{drive, Arrivals};
// The deprecated shims are compared against the unified drive on purpose.
#[allow(deprecated)]
use recross::loadgen::{drive_sharded, drive_single};
use recross::workload::Query;
use std::time::Duration;

const SCALE: f64 = 0.02;
const SHARDS: usize = 3;
const SLACK: f64 = 0.10;

fn cfg_small() -> Config {
    let mut cfg = Config::paper_default();
    cfg.workload.dataset = "software".into();
    cfg.workload.history_queries = 500;
    cfg.workload.eval_queries = 96;
    cfg.scheme.batch_size = 32;
    cfg
}

fn build(scheme: Scheme) -> Prepared {
    Deployment::of(cfg_small())
        .scheme(scheme)
        .scale(SCALE)
        .build()
        .unwrap()
}

fn policy() -> BatchPolicy {
    BatchPolicy {
        max_batch: 16,
        max_wait: Duration::from_micros(5),
    }
}

/// The four CLI-facing schemes the facade must reproduce exactly.
const SCHEMES: [Scheme; 4] = [
    Scheme::Naive,
    Scheme::Frequency,
    Scheme::Nmars,
    Scheme::ReCross,
];

#[test]
fn builder_engine_matches_legacy_offline_phase_for_all_schemes() {
    for scheme in SCHEMES {
        let prepared = build(scheme);
        let legacy = OfflinePhase::run(&cfg_small(), scheme, SCALE).unwrap();
        assert_eq!(prepared.scheme(), scheme);
        assert_eq!(prepared.history().queries, legacy.history.queries, "{scheme:?}");
        assert_eq!(prepared.eval().queries, legacy.eval.queries, "{scheme:?}");
        assert_eq!(
            prepared.engine().physical_crossbars(),
            legacy.engine.physical_crossbars(),
            "{scheme:?}"
        );
        // Exact ExecStats equality over the whole eval trace.
        let bs = cfg_small().scheme.batch_size;
        let via_builder = prepared.engine().run_trace(prepared.eval(), bs);
        let via_legacy = legacy.engine.run_trace(&legacy.eval, bs);
        assert_eq!(via_builder, via_legacy, "{scheme:?} run_trace diverged");
    }
}

#[test]
#[allow(deprecated)]
fn unified_drive_is_bit_identical_to_the_deprecated_shims() {
    for scheme in SCHEMES {
        let prepared = build(scheme);
        let queries = &prepared.eval().queries;
        let arrivals = Arrivals::poisson(150_000.0, 11).take(queries.len());
        let p = policy();
        if scheme == Scheme::Nmars {
            // The open-loop driver serves the MAC dataflow only; the
            // builder refuses instead of mispricing.
            assert!(prepared.sim().is_err());
            assert!(prepared.sim_sharded(SHARDS, SLACK).is_err());
            continue;
        }
        // Single pool: drive(SimBackend) == drive_single(four-accessor).
        let sched = prepared.scheduler();
        let legacy_single = drive_single(&sched, queries, &arrivals, &p);
        let new_single = drive(&prepared.sim().unwrap(), queries, &arrivals, &p);
        assert_eq!(legacy_single, new_single, "{scheme:?} single drive diverged");
        // Sharded: drive(SimBackend::sharded) == drive_sharded(legacy).
        let shared = PoolShared::from_engine(prepared.engine());
        let plan =
            ShardPlan::by_locality(&shared.mapping, prepared.history(), SHARDS, SLACK);
        let legacy_sharded = drive_sharded(&shared, &plan, queries, &arrivals, &p);
        let new_sharded = drive(
            &prepared.sim_sharded(SHARDS, SLACK).unwrap(),
            queries,
            &arrivals,
            &p,
        );
        assert_eq!(legacy_sharded, new_sharded, "{scheme:?} sharded drive diverged");
    }
}

#[test]
fn builder_sharded_sims_match_legacy_cluster_simulators() {
    for scheme in SCHEMES {
        if scheme == Scheme::Nmars {
            continue; // no sharded dataflow
        }
        let prepared = build(scheme);
        let shared = PoolShared::from_engine(prepared.engine());
        let plan =
            ShardPlan::by_locality(&shared.mapping, prepared.history(), SHARDS, SLACK);
        let bs = cfg_small().scheme.batch_size;
        // Routed: spread placement + p2c, builder pieces vs legacy pieces.
        let freqs = recross::allocation::group_frequencies(
            prepared.engine().mapping(),
            prepared.history(),
        );
        let spread = ReplicaPlan::spread(&plan, &shared.replication, &freqs);
        let routed_a = simulate_with_replicas(
            &shared,
            &plan,
            &spread,
            prepared.eval(),
            bs,
            RoutePolicy::PowerOfTwo,
        );
        let legacy_off = OfflinePhase::run(&cfg_small(), scheme, SCALE).unwrap();
        let legacy_shared = PoolShared::from_engine(&legacy_off.engine);
        let legacy_plan = ShardPlan::by_locality(
            &legacy_shared.mapping,
            &legacy_off.history,
            SHARDS,
            SLACK,
        );
        let legacy_freqs = recross::allocation::group_frequencies(
            legacy_off.engine.mapping(),
            &legacy_off.history,
        );
        let legacy_spread =
            ReplicaPlan::spread(&legacy_plan, &legacy_shared.replication, &legacy_freqs);
        let routed_b = simulate_with_replicas(
            &legacy_shared,
            &legacy_plan,
            &legacy_spread,
            &legacy_off.eval,
            bs,
            RoutePolicy::PowerOfTwo,
        );
        assert_eq!(routed_a, routed_b, "{scheme:?} routed sim diverged");
        // Pinned: the legacy closed-loop sharded simulator across paths.
        let pinned_a = simulate_sharded(&shared, &plan, prepared.eval(), bs);
        let pinned_b = simulate_sharded(&legacy_shared, &legacy_plan, &legacy_off.eval, bs);
        assert_eq!(pinned_a, pinned_b, "{scheme:?} pinned sim diverged");
    }
}

/// An integer-valued table over the prepared mapping: embedding `e` is
/// `[e*D, e*D+1, ..]`, so reductions are exact integer sums in f32 and
/// equality can be `==`, not a tolerance.
fn integer_store(prepared: &Prepared) -> EmbeddingStore {
    let mapping = prepared.engine().mapping();
    let dim = prepared.config().hardware.embedding_dim;
    let rows = prepared.config().hardware.xbar_rows;
    let n = mapping.num_embeddings();
    // Keep values small so any sum stays far below 2^24 (f32-exact).
    let table: Vec<f32> = (0..n * dim).map(|i| (i % 251) as f32).collect();
    EmbeddingStore::from_table(mapping, dim, rows, table)
}

#[test]
fn live_sharded_backends_reduce_exactly_on_integer_tables() {
    for mode in [ShardingMode::Pinned, ShardingMode::ReplicaRouted] {
        let prepared = build(Scheme::ReCross);
        prepared.install_store(integer_store(&prepared)).unwrap();
        let ccfg = ClusterConfig {
            shards: SHARDS,
            mode,
            ..Default::default()
        };
        let pool = Sharded::spawn(&prepared, &ccfg).unwrap();
        assert_eq!(pool.executors(), SHARDS);
        assert_eq!(pool.mode(), mode);
        let queries: Vec<Query> =
            prepared.eval().queries.iter().take(48).cloned().collect();
        let out = pool.reduce_many(&queries).unwrap();
        assert_eq!(out.len(), queries.len());
        for (q, r) in queries.iter().zip(&out) {
            let expect = prepared.store().reduce_reference(&q.items);
            assert_eq!(r.reduced, expect, "mode {mode:?}: inexact reduction");
        }
        // The per-executor status vocabulary is served.
        let status = pool.status().unwrap();
        assert_eq!(status.len(), SHARDS);
        let served: u64 = status.iter().map(|s| s.queries).sum();
        assert!(served > 0, "shards reported no served sub-queries");
    }
}

#[test]
fn live_sharded_timing_twin_matches_the_simulator_bit_for_bit() {
    // Driving the *live* pool's deterministic timing twin must equal
    // driving the thread-free simulator over the same plan — whatever
    // routing mode the live reduce path uses (the twin is always
    // ownership-pinned).
    for mode in [ShardingMode::Pinned, ShardingMode::ReplicaRouted] {
        let prepared = build(Scheme::ReCross);
        let ccfg = ClusterConfig {
            shards: SHARDS,
            slack: SLACK,
            mode,
            ..Default::default()
        };
        let pool = Sharded::spawn(&prepared, &ccfg).unwrap();
        let sim = prepared.sim_sharded(SHARDS, SLACK).unwrap();
        let queries = &prepared.eval().queries;
        let arrivals = Arrivals::poisson(120_000.0, 17).take(queries.len());
        let p = policy();
        let live_twin = drive(&pool, queries, &arrivals, &p);
        let simulated = drive(&sim, queries, &arrivals, &p);
        assert_eq!(live_twin, simulated, "mode {mode:?}: timing twins diverged");
    }
}

#[test]
fn sim_backend_reduces_exactly_on_integer_tables() {
    let prepared = build(Scheme::ReCross);
    prepared.install_store(integer_store(&prepared)).unwrap();
    let backend = prepared
        .sim_sharded(SHARDS, SLACK)
        .unwrap()
        .with_store(prepared.store());
    let queries: Vec<Query> = prepared.eval().queries.iter().take(48).cloned().collect();
    let out = backend.reduce_many(&queries).unwrap();
    for (q, r) in queries.iter().zip(&out) {
        assert_eq!(
            r.reduced,
            prepared.store().reduce_reference(&q.items),
            "sim reduction diverged"
        );
    }
    // Backend vocabulary sanity.
    assert_eq!(backend.executors(), SHARDS);
    assert!(backend.name().contains("sharded"));
}

#[test]
fn dyn_backend_objects_are_interchangeable() {
    // The whole point of the facade: hold any backend behind one `&dyn`.
    let prepared = build(Scheme::ReCross);
    prepared.install_store(integer_store(&prepared)).unwrap();
    let sim_single = prepared.sim().unwrap().with_store(prepared.store());
    let sim_sharded = prepared
        .sim_sharded(SHARDS, SLACK)
        .unwrap()
        .with_store(prepared.store());
    let backends: Vec<&dyn Backend> = vec![&sim_single, &sim_sharded];
    let queries: Vec<Query> = prepared.eval().queries.iter().take(16).cloned().collect();
    let mut all: Vec<Vec<Vec<f32>>> = Vec::new();
    for b in &backends {
        let out = b.reduce_many(&queries).unwrap();
        all.push(out.into_iter().map(|r| r.reduced).collect());
    }
    // Integer tables: every backend agrees exactly, whatever the scatter.
    assert_eq!(all[0], all[1], "backends disagree on integer reductions");
    // And every backend drives through the same open-loop loop.
    let arrivals = Arrivals::poisson(100_000.0, 5).take(queries.len());
    for b in &backends {
        let r = drive(*b, &queries, &arrivals, &policy());
        assert_eq!(r.queries(), queries.len());
    }
}
