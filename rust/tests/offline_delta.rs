//! Differential fuzz for the incremental offline phase
//! (`PreparedEngine::refresh` vs full recompute).
//!
//! The identity contract under test (documented in `engine/refresh.rs`):
//!
//! 1. **Graph exactness at any scope** — the incrementally maintained
//!    `WindowGraph` equals a batch `CoGraph::build` over the slid window
//!    bit-identically (content-seeded pair sampling makes add/retire
//!    true inverses).
//! 2. **Full scope == fresh prepare** — `refresh_full` produces the
//!    bit-identical mapping and replication as `Engine::prepare` over
//!    the slid window (every delta stage is the generalisation the full
//!    stage delegates to).
//! 3. **Partial scope preserves clean state** — ids outside
//!    `moved_ids` keep their exact slot, groups outside
//!    `changed_groups` keep their exact copy count.
//! 4. **Work scales with the delta** — localized drift on a big table
//!    touches O(delta) ids/groups, not O(table).

use recross::config::Config;
use recross::engine::{Engine, PreparedEngine, Scheme};
use recross::graph::{CoGraph, DeltaParams};
use recross::workload::{Query, Trace};

/// splitmix64 — the same tiny deterministic generator the library's
/// sampling layer is built on; good enough to derive fuzz configs.
fn split(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

fn unit(state: &mut u64) -> f64 {
    (split(state) >> 11) as f64 / (1u64 << 53) as f64
}

/// One Zipf-ish query: items drawn by a power-law transform of a
/// uniform draw through a popularity permutation (`perm[0]` hottest).
fn zipf_query(state: &mut u64, perm: &[u32], alpha: f64, max_len: usize) -> Query {
    let n = perm.len();
    let len = 1 + (split(state) as usize) % max_len;
    let items: Vec<u32> = (0..len)
        .map(|_| {
            let idx = ((n as f64) * unit(state).powf(alpha)) as usize;
            perm[idx.min(n - 1)]
        })
        .collect();
    Query::new(items)
}

fn zipf_trace(state: &mut u64, n_emb: u32, perm: &[u32], alpha: f64, queries: usize) -> Trace {
    Trace {
        num_embeddings: n_emb,
        queries: (0..queries)
            .map(|_| zipf_query(state, perm, alpha, 4))
            .collect(),
    }
}

/// The popularity order for a drift seed: identity rotated by `shift`
/// (new items become hot, old hot items cool down).
fn rotated(n: u32, shift: u32) -> Vec<u32> {
    (0..n).map(|i| (i + shift) % n).collect()
}

fn fuzz_cfg(group_size: usize) -> Config {
    let mut cfg = Config::paper_default();
    cfg.scheme.group_size = group_size;
    cfg.scheme.batch_size = 64;
    cfg
}

const SCHEMES: [Scheme; 3] = [Scheme::ReCross, Scheme::ReCrossNoDup, Scheme::ReCrossNoSwitch];

/// ≥200 seeded configs over drifting Zipf workloads. Each config checks
/// contracts 1–3 above; a mismatch prints the config seed so the case
/// can be replayed in isolation.
#[test]
fn incremental_refresh_matches_full_recompute_200_configs() {
    for seed in 0..200u64 {
        let mut rng = seed.wrapping_mul(0x5851_F42D_4C95_7F2D).wrapping_add(1);
        let n_emb = 16 + (split(&mut rng) % 49) as u32; // 16..=64
        let group_size = [2usize, 4, 8][(split(&mut rng) % 3) as usize];
        let window_len = 40 + (split(&mut rng) % 61) as usize; // 40..=100
        let alpha = 1.5 + 1.5 * unit(&mut rng);
        let scheme = SCHEMES[(split(&mut rng) % 3) as usize];
        let cfg = fuzz_cfg(group_size);

        let base_perm = rotated(n_emb, 0);
        let window = zipf_trace(&mut rng, n_emb, &base_perm, alpha, window_len);

        // The drift: popularity rotates by a third of the catalogue.
        let drift_perm = rotated(n_emb, n_emb / 3);
        let added = zipf_trace(
            &mut rng,
            n_emb,
            &drift_perm,
            alpha,
            10 + (split(&mut rng) % 31) as usize,
        )
        .queries;
        let retire = (split(&mut rng) as usize) % (window_len / 2);

        // Contract 2: full-scope refresh == fresh prepare on the slid
        // window, bit-identically.
        let mut full = PreparedEngine::prepare(scheme, &window, &cfg);
        full.refresh_full(&added, retire);
        let mut slid = window.clone();
        slid.queries.drain(..retire);
        slid.queries.extend_from_slice(&added);
        let oracle = Engine::prepare(scheme, &CoGraph::build(&slid), &slid, &cfg);
        assert_eq!(
            full.engine().mapping().groups,
            oracle.mapping().groups,
            "config {seed}: full-scope groups diverge from fresh prepare"
        );
        assert_eq!(
            full.engine().mapping().slot,
            oracle.mapping().slot,
            "config {seed}: full-scope slots diverge from fresh prepare"
        );
        assert_eq!(
            full.engine().replication().copies,
            oracle.replication().copies,
            "config {seed}: full-scope replication diverges from fresh prepare"
        );

        // Contracts 1 and 3 on the *partial* path.
        let params = if seed % 2 == 0 {
            DeltaParams::default()
        } else {
            DeltaParams::sensitive()
        };
        let mut pe = PreparedEngine::prepare(scheme, &window, &cfg);
        let before = pe.engine().clone();
        let report = pe.refresh_with(&added, retire, &params);

        // Contract 1: the maintained graph equals a batch rebuild.
        assert_eq!(
            pe.window_graph().to_cograph(),
            CoGraph::build(&slid),
            "config {seed}: window graph diverged from batch rebuild"
        );
        assert_eq!(pe.window().queries, slid.queries, "config {seed}: window state");

        // Contract 3: clean ids keep their slots, clean groups their
        // copy counts.
        assert!(!report.full);
        assert_eq!(report.ids_total, n_emb as usize);
        for v in 0..n_emb {
            if !report.grouping.moved_ids.contains(&v) {
                assert_eq!(
                    pe.engine().mapping().slot_of(v),
                    before.mapping().slot_of(v),
                    "config {seed}: clean id {v} moved"
                );
            }
        }
        let common = pe
            .engine()
            .mapping()
            .num_groups()
            .min(before.mapping().num_groups()) as u32;
        for g in 0..common {
            if !report.grouping.changed_groups.contains(&g) {
                assert_eq!(
                    pe.engine().mapping().groups[g as usize],
                    before.mapping().groups[g as usize],
                    "config {seed}: clean group {g} re-derived"
                );
                assert_eq!(
                    pe.engine().replication().copies_of(g),
                    before.replication().copies_of(g),
                    "config {seed}: clean group {g} re-planned"
                );
            }
        }
    }
}

/// Contract 4: on a big table with localized drift, the refresh touches
/// O(delta) ids and groups — not the whole catalogue. This is the work
/// counter the incremental path exists for.
#[test]
fn incremental_work_scales_with_delta_not_table() {
    const N: u32 = 512;
    const CLIQUES: u32 = N / 4;
    let cfg = fuzz_cfg(4);
    // Uniform traffic over 128 disjoint 4-cliques: each query hits one
    // clique exactly, round-robin, so every clique forms its own group.
    let window = Trace {
        num_embeddings: N,
        queries: (0..256)
            .map(|i| {
                let c = (i % CLIQUES) * 4;
                Query::new(vec![c, c + 1, c + 2, c + 3])
            })
            .collect(),
    };
    let mut pe = PreparedEngine::prepare(Scheme::ReCross, &window, &cfg);
    let groups_total = pe.engine().mapping().num_groups();

    // Drift hammers clique 0 only; every other clique's frequencies are
    // untouched, so at default thresholds only clique 0's group is dirty.
    let added: Vec<Query> = (0..40).map(|_| Query::new(vec![0, 1, 2, 3])).collect();
    let report = pe.refresh(&added, 0);

    assert_eq!(report.ids_total, N as usize);
    assert!(
        report.ids_moved <= 16,
        "localized drift moved {} of {} ids",
        report.ids_moved,
        report.ids_total
    );
    assert!(
        report.groups_changed <= 4,
        "localized drift re-derived {} of {} groups",
        report.groups_changed,
        groups_total
    );
    assert!(report.groups_total >= groups_total - 4);
    // The untouched tail keeps its layout bit-identically.
    assert!(report.ids_moved < report.ids_total / 8);
}

/// Determinism under parallelism: every parallel fan-out in the offline
/// phase (co-graph pair counting, component-parallel Algorithm 1,
/// marginal-gain scoring for replication) merges per-worker partials in
/// fixed worker order, so the result is bit-identical for ANY worker
/// count — the thread count is a throughput knob, never a semantics knob.
///
/// 50 seeded drifting-Zipf configs, each run at 1, 2, and 8 workers.
/// These entry points do not reset the global worker count (unlike
/// `PreparedEngine::prepare`, which re-shapes the substrate from
/// `cfg.offline.workers`), so sweeping `par::set_default_workers` here
/// drives every width through the same code paths.
#[test]
fn offline_phase_is_bit_identical_across_worker_counts() {
    use recross::allocation::{group_frequencies, plan_replication, Replication};
    use recross::grouping::{regroup_subset, GroupingDelta, Mapping};
    use recross::util::par;

    for seed in 0..50u64 {
        let mut rng = seed.wrapping_mul(0xA076_1D64_78BD_642F).wrapping_add(1);
        let n_emb = 24 + (split(&mut rng) % 41) as u32; // 24..=64
        let group_size = [2usize, 4, 8][(split(&mut rng) % 3) as usize];
        let window_len = 60 + (split(&mut rng) % 81) as usize; // 60..=140
        let alpha = 1.5 + 1.5 * unit(&mut rng);
        let scheme = SCHEMES[(split(&mut rng) % 3) as usize];
        let cfg = fuzz_cfg(group_size);

        // Base traffic plus a drifted tail, so the regroup below sees a
        // dirty set with real affinity changes behind it.
        let mut window = zipf_trace(&mut rng, n_emb, &rotated(n_emb, 0), alpha, window_len);
        let drift_perm = rotated(n_emb, n_emb / 3);
        window.queries.extend(zipf_trace(&mut rng, n_emb, &drift_perm, alpha, 30).queries);
        // A deterministic third of the catalogue is marked dirty.
        let dirty: Vec<u32> = (0..n_emb).filter(|v| v % 3 == 0).collect();

        type Snapshot = (CoGraph, Mapping, GroupingDelta, Vec<u64>, Replication);
        let run = |workers: usize| -> Snapshot {
            par::set_default_workers(workers);
            let graph = CoGraph::build(&window);
            let engine = Engine::prepare(scheme, &graph, &window, &cfg);
            let (mapping, delta) = regroup_subset(&graph, engine.mapping(), &dirty);
            let freqs = group_frequencies(&mapping, &window);
            let plan = plan_replication(&freqs, cfg.scheme.batch_size, cfg.scheme.dup_ratio);
            (graph, mapping, delta, freqs, plan)
        };

        let serial = run(1);
        for workers in [2usize, 8] {
            let wide = run(workers);
            assert_eq!(
                serial.0, wide.0,
                "config {seed}: CoGraph::build diverges at {workers} workers"
            );
            assert_eq!(
                serial.1.groups, wide.1.groups,
                "config {seed}: regroup_subset groups diverge at {workers} workers"
            );
            assert_eq!(
                serial.1.slot, wide.1.slot,
                "config {seed}: regroup_subset slots diverge at {workers} workers"
            );
            assert_eq!(
                (&serial.2.changed_groups, &serial.2.moved_ids),
                (&wide.2.changed_groups, &wide.2.moved_ids),
                "config {seed}: grouping delta diverges at {workers} workers"
            );
            assert_eq!(
                serial.3, wide.3,
                "config {seed}: group_frequencies diverge at {workers} workers"
            );
            assert_eq!(
                serial.4.copies, wide.4.copies,
                "config {seed}: plan_replication diverges at {workers} workers"
            );
        }
    }
    // Leave the process-global substrate back at auto for other tests.
    par::set_default_workers(0);
}
