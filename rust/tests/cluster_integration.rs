//! Cluster-layer integration tests: the sharded scatter-gather reduction
//! must be *exactly* the single-pool reduction, and the shard executors'
//! bookkeeping must conserve the pool-level quantities.
//!
//! Exactness strategy: the store is loaded with integer-valued f32s, so
//! every summation order yields bit-identical results (integer f32 adds
//! are exact well below 2^24) — any mismatch is a routing bug (lost,
//! duplicated, or misdirected lookups), not float noise.

use recross::cluster::{
    simulate_sharded, Cluster, ClusterConfig, PartitionPolicy, PoolShared, ShardPlan,
};
use recross::config::Config;
use recross::coordinator::{BatchPolicy, EmbeddingStore};
use recross::engine::{Engine, Scheme};
use recross::graph::CoGraph;
use recross::workload::{generate, DatasetSpec, Query, Trace};

struct Fixture {
    engine: Engine,
    history: Trace,
    eval: Trace,
    store: EmbeddingStore,
    cfg: Config,
}

fn fixture() -> Fixture {
    let spec = DatasetSpec::by_name("software").unwrap().scaled(0.02);
    let (history, eval) = generate(&spec, 600, 200, 42);
    let graph = CoGraph::build(&history);
    let mut cfg = Config::paper_default();
    cfg.scheme.batch_size = 64;
    let engine = Engine::prepare(Scheme::ReCross, &graph, &history, &cfg);
    // Integer-valued table in [-8, 8]: exact under any summation order.
    let dim = cfg.hardware.embedding_dim;
    let n = engine.mapping().num_embeddings();
    let table: Vec<f32> = (0..n * dim)
        .map(|i| ((i.wrapping_mul(2_654_435_761)) % 17) as f32 - 8.0)
        .collect();
    let store = EmbeddingStore::from_table(engine.mapping(), dim, cfg.hardware.xbar_rows, table);
    Fixture {
        engine,
        history,
        eval,
        store,
        cfg,
    }
}

fn shared_of(f: &Fixture) -> PoolShared {
    PoolShared::from_engine(&f.engine)
}

#[test]
fn sharded_reduction_bit_identical_to_single_pool() {
    let f = fixture();
    let plan = ShardPlan::by_locality(f.engine.mapping(), &f.history, 4, 0.10);
    let cluster =
        Cluster::spawn_from_parts(shared_of(&f), &f.store, plan, BatchPolicy::default()).unwrap();
    let handle = cluster.handle();

    let queries: Vec<Query> = f.eval.queries.iter().take(100).cloned().collect();
    let responses = handle.reduce_many(&queries).unwrap();
    assert_eq!(responses.len(), queries.len());
    for (q, r) in queries.iter().zip(&responses) {
        let expect = f.store.reduce_reference(&q.items);
        assert_eq!(
            r.reduced, expect,
            "sharded reduction differs from single-pool reference for {:?}",
            q.items
        );
        if !q.is_empty() {
            assert!((1..=4).contains(&r.fanout), "fanout {} out of range", r.fanout);
        }
    }
}

#[test]
fn sharded_activations_conserved() {
    // Splitting by shard must not create or destroy activations: groups
    // partition across shards, so per-query distinct-group counts sum
    // exactly to the single-pool count.
    let f = fixture();
    let plan = ShardPlan::by_locality(f.engine.mapping(), &f.history, 4, 0.10);
    let cluster =
        Cluster::spawn_from_parts(shared_of(&f), &f.store, plan, BatchPolicy::default()).unwrap();
    let handle = cluster.handle();

    let queries: Vec<Query> = f.eval.queries.iter().take(128).cloned().collect();
    let responses = handle.reduce_many(&queries).unwrap();
    let sharded_acts: u64 = responses.iter().map(|r| r.activations).sum();
    let reference = f
        .engine
        .count_activations(&Trace {
            num_embeddings: f.eval.num_embeddings,
            queries: queries.clone(),
        });
    assert_eq!(sharded_acts, reference);

    // Shard executors saw every lookup exactly once.
    let statuses = handle.shard_status().unwrap();
    let lookups: u64 = statuses.iter().map(|s| s.lookups).sum();
    let expect: u64 = queries.iter().map(|q| q.len() as u64).sum();
    assert_eq!(lookups, expect);
    let sim_acts: u64 = statuses.iter().map(|s| s.sim.activations).sum();
    assert_eq!(sim_acts, reference);
}

#[test]
fn hash_and_locality_plans_agree_with_live_pool() {
    // The hash-partitioned pool must be just as exact as the locality one.
    let f = fixture();
    let ring = recross::cluster::HashRing::new(4, 128);
    let plan = ShardPlan::by_hash(f.engine.mapping().num_groups(), &ring);
    let cluster =
        Cluster::spawn_from_parts(shared_of(&f), &f.store, plan, BatchPolicy::default()).unwrap();
    let handle = cluster.handle();
    for q in f.eval.queries.iter().take(40) {
        let r = handle.reduce(&q.items).unwrap();
        assert_eq!(r.reduced, f.store.reduce_reference(&q.items));
    }
}

#[test]
fn locality_partition_fans_out_no_worse_than_hash() {
    let f = fixture();
    let mapping = f.engine.mapping();
    let ring = recross::cluster::HashRing::new(4, 128);
    let hash = ShardPlan::by_hash(mapping.num_groups(), &ring);
    let locality = ShardPlan::by_locality(mapping, &f.history, 4, 0.25);
    let h_mean = hash.fanout_histogram(mapping, &f.eval).mean();
    let l_mean = locality.fanout_histogram(mapping, &f.eval).mean();
    assert!(l_mean >= 1.0);
    // 10% tolerance: hash is unbalanced at this tiny group count, which
    // can deflate its fan-out; locality must still be in its ballpark.
    assert!(
        l_mean <= h_mean * 1.10 + 1e-9,
        "locality fan-out {l_mean:.3} much worse than hash {h_mean:.3}"
    );
}

#[test]
fn sharded_server_handle_serves_requests_in_order() {
    use recross::coordinator::{Request, ShardedServerHandle};
    let f = fixture();
    let plan = ShardPlan::by_locality(f.engine.mapping(), &f.history, 4, 0.10);
    let cluster =
        Cluster::spawn_from_parts(shared_of(&f), &f.store, plan, BatchPolicy::default()).unwrap();
    let front = ShardedServerHandle::new(cluster.handle());

    let reqs: Vec<Request> = f
        .eval
        .queries
        .iter()
        .take(50)
        .enumerate()
        .map(|(i, q)| Request {
            id: 1000 + i as u64,
            dense: vec![0.0; 13],
            items: q.items.clone(),
        })
        .collect();
    let expected: Vec<Vec<f32>> = reqs
        .iter()
        .map(|r| f.store.reduce_reference(&r.items))
        .collect();
    let responses = front.infer_many(reqs).unwrap();
    assert_eq!(responses.len(), 50);
    for (i, (r, want)) in responses.iter().zip(&expected).enumerate() {
        assert_eq!(r.id, 1000 + i as u64, "responses out of request order");
        assert_eq!(&r.reduced, want);
        assert!(r.logit.is_nan(), "sharded path must not fabricate a logit");
    }

    // Single-request path agrees with the batch path.
    let one = front
        .infer(Request {
            id: 7,
            dense: vec![0.0; 13],
            items: f.eval.queries[0].items.clone(),
        })
        .unwrap();
    assert_eq!(one.id, 7);
    assert_eq!(one.reduced, expected[0]);
}

#[test]
fn cluster_rejects_nmars_scheme() {
    let mut cfg = Config::paper_default();
    cfg.workload.history_queries = 200;
    cfg.workload.eval_queries = 50;
    let err = Cluster::build(&cfg, Scheme::Nmars, 0.02, &ClusterConfig::default());
    assert!(err.is_err(), "nmars has no sharded dataflow and must be refused");
}

#[test]
fn single_shard_cluster_equals_engine_simulation() {
    let f = fixture();
    let shared = shared_of(&f);
    let plan = ShardPlan::from_assignment(vec![0; shared.mapping.num_groups()], 1);
    let sharded = simulate_sharded(&shared, &plan, &f.eval, f.cfg.scheme.batch_size);
    let reference = f.engine.run_trace(&f.eval, f.cfg.scheme.batch_size);
    assert_eq!(sharded, reference, "one-shard pool must equal the single pool");
}

#[test]
fn cluster_build_from_config_end_to_end() {
    // The `recross cluster` CLI path: offline phase -> partition -> spawn
    // -> serve, via Cluster::build.
    let mut cfg = Config::paper_default();
    cfg.workload.history_queries = 400;
    cfg.workload.eval_queries = 100;
    let ccfg = ClusterConfig {
        shards: 3,
        policy: PartitionPolicy::Locality,
        ..Default::default()
    };
    let bundle = Cluster::build(&cfg, Scheme::ReCross, 0.02, &ccfg).unwrap();
    assert_eq!(bundle.cluster.num_shards(), 3);
    let handle = bundle.cluster.handle();
    let queries: Vec<Query> = bundle.eval.queries.iter().take(32).cloned().collect();
    let responses = handle.reduce_many(&queries).unwrap();
    // Random (non-integer) store: allow float reassociation noise.
    for (q, r) in queries.iter().zip(&responses) {
        let expect = bundle.store.reduce_reference(&q.items);
        for (a, b) in r.reduced.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }
    let merged = handle.merged_sim().unwrap();
    assert!(merged.queries > 0);
    let max_shard = handle
        .shard_status()
        .unwrap()
        .iter()
        .map(|s| s.sim.completion_ns)
        .fold(0.0f64, f64::max);
    assert_eq!(merged.completion_ns, max_shard);
}
