//! CLI integration tests: drive the `recross` binary end-to-end the way a
//! user would (cargo exposes the built binary via `CARGO_BIN_EXE_recross`).

use std::process::Command;

fn recross(args: &[&str]) -> std::process::Output {
    Command::new(env!("CARGO_BIN_EXE_recross"))
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("failed to spawn recross")
}

#[test]
fn help_prints_usage() {
    let out = recross(&["--help"]);
    let text = String::from_utf8_lossy(&out.stderr);
    assert!(text.contains("USAGE"), "{text}");
    assert!(text.contains("--figure"));
}

#[test]
fn unknown_command_fails_with_usage() {
    let out = recross(&["frobnicate"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown command"));
}

#[test]
fn report_table1() {
    let out = recross(&["report", "--figure", "table1"]);
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("TABLE I"));
    assert!(text.contains("sports"));
    assert!(text.contains("962876") || text.contains("962,876"));
}

#[test]
fn report_fig9_tiny() {
    let out = recross(&[
        "report", "--figure", "fig9", "--scale", "0.01", "--history", "300", "--eval", "80",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("recross"));
    assert!(text.contains("naive"));
}

#[test]
fn report_unknown_figure_fails() {
    let out = recross(&["report", "--figure", "fig99"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown figure"));
}

#[test]
fn generate_then_analyze_roundtrip() {
    let path = std::env::temp_dir().join("recross_cli_test.rxtr");
    let path_s = path.to_str().unwrap();
    let out = recross(&[
        "generate", "--dataset", "software", "--scale", "0.02", "--queries", "200", "--out",
        path_s,
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("200 queries"));

    let out = recross(&["analyze", path_s]);
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("queries:          200"));
    assert!(text.contains("power-law"));
    let _ = std::fs::remove_file(&path);
}

#[test]
fn analyze_missing_file_fails_cleanly() {
    let out = recross(&["analyze", "/nonexistent/trace.rxtr"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("error"));
}

#[test]
fn autotune_picks_a_knee() {
    let out = recross(&[
        "autotune", "--dataset", "software", "--scale", "0.02", "--history", "400", "--eval",
        "100",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("<-- knee"));
    assert!(text.contains("chosen dup_ratio"));
}

#[test]
fn config_file_accepted() {
    let out = recross(&[
        "report", "--config", "configs/paper.toml", "--figure", "table1",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
}

#[test]
fn bad_config_rejected() {
    let p = std::env::temp_dir().join("recross_bad_config.toml");
    std::fs::write(&p, "[scheme]\ndup_ratio = 7.0\n").unwrap();
    let out = recross(&["report", "--config", p.to_str().unwrap(), "--figure", "fig9"]);
    assert!(!out.status.success());
    let _ = std::fs::remove_file(&p);
}

#[test]
fn serve_open_loop_reports_tail_latency_and_is_bit_reproducible() {
    // The open-loop simulator needs no PJRT artifacts and no threads:
    // identical flags must produce byte-identical stdout.
    let run = || {
        recross(&[
            "serve", "--arrivals", "poisson", "--rate", "200000", "--requests", "128",
            "--dataset", "software", "--scale", "0.02", "--history", "300", "--eval", "64",
            "--seed", "7", "--shards", "2",
        ])
    };
    let out = run();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("open-loop serving sim"), "{text}");
    for needle in ["p50", "p95", "p99", "p999", "single-pool", "sharded(2)", "mean-depth"] {
        assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
    }
    assert!(text.contains("per-shard backlog"));
    let again = run();
    assert_eq!(out.stdout, again.stdout, "open-loop sim must be bit-reproducible");
}

#[test]
fn config_file_overrides_reach_the_live_batcher() {
    // `scheme.max_wait_us` from a --config TOML must reach the serving
    // batcher (the open-loop sim prints — and uses — the live policy).
    let p = std::env::temp_dir().join("recross_batcher_config.toml");
    std::fs::write(&p, "[scheme]\nmax_wait_us = 9\n").unwrap();
    let base = [
        "serve", "--arrivals", "poisson", "--rate", "200000", "--requests", "64",
        "--dataset", "software", "--scale", "0.02", "--history", "300", "--eval", "64",
        "--seed", "7",
    ];
    let mut with_cfg = base.to_vec();
    with_cfg.extend(["--config", p.to_str().unwrap()]);
    let out = recross(&with_cfg);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("wait=9µs"), "TOML wait did not reach the batcher:\n{text}");

    // An explicitly passed CLI flag outranks the TOML value...
    let mut with_flag = with_cfg.clone();
    with_flag.extend(["--max-wait-us", "5"]);
    let out = recross(&with_flag);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("wait=5µs"), "CLI flag did not outrank TOML:\n{text}");

    // ...and without either, the open-loop default (5 µs) still applies.
    let out = recross(&base);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("wait=5µs"), "default wait changed:\n{text}");
    let _ = std::fs::remove_file(&p);
}

#[test]
fn serve_open_loop_rejects_unknown_process_and_nmars() {
    let out = recross(&["serve", "--arrivals", "fractal"]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown arrival process"));

    let out = recross(&[
        "serve", "--arrivals", "poisson", "--scheme", "nmars", "--scale", "0.02", "--history",
        "300", "--eval", "64",
    ]);
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("MAC dataflow"));
}

#[test]
fn serve_smoke_when_artifacts_exist() {
    if !recross::runtime::artifacts_available(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")) {
        eprintln!("skipping serve smoke: artifacts missing");
        return;
    }
    let out = recross(&[
        "serve", "--dataset", "software", "--scale", "0.02", "--history", "300", "--eval", "64",
        "--requests", "16",
    ]);
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("throughput"));
    assert!(text.contains("served 16 requests"));
}
