//! End-to-end tests of the open-loop traffic engine over a real offline
//! phase: arrival processes → v2 timed traces → simulated-time driver →
//! tail-latency telemetry, for the single-pool and sharded back-ends —
//! all built through the `deploy` facade.

use recross::config::Config;
use recross::coordinator::BatchPolicy;
use recross::deploy::{Deployment, Prepared};
use recross::engine::Scheme;
use recross::loadgen::{drive, ArrivalKind, Arrivals};
use recross::sched::Scratch;
use recross::workload::{DatasetSpec, Generator, TimedTrace, Trace};
use std::time::Duration;

const SCALE: f64 = 0.03;
const QUERIES: usize = 384;

fn setup() -> (Prepared, Trace) {
    let mut cfg = Config::paper_default();
    cfg.workload.dataset = "software".into();
    cfg.workload.history_queries = 800;
    cfg.workload.eval_queries = 64;
    let prepared = Deployment::of(cfg.clone())
        .scheme(Scheme::ReCross)
        .scale(SCALE)
        .build()
        .unwrap();
    let spec = DatasetSpec::by_name("software").unwrap().scaled(SCALE);
    let gen = Generator::new(&spec, cfg.workload.seed);
    let trace = gen.trace(QUERIES, 99);
    (prepared, trace)
}

fn policy(max_batch: usize, wait_us: u64) -> BatchPolicy {
    BatchPolicy {
        max_batch,
        max_wait: Duration::from_micros(wait_us),
    }
}

#[test]
fn open_loop_end_to_end_is_deterministic_across_backends() {
    let (prepared, trace) = setup();
    let single = prepared.sim().unwrap();
    let sharded = prepared.sim_sharded(4, 0.10).unwrap();
    let p = policy(32, 5);
    for kind in [ArrivalKind::Poisson, ArrivalKind::Bursty, ArrivalKind::Diurnal] {
        let arrivals = Arrivals::from_kind(kind, 100_000.0, 5).take(QUERIES);
        let s1 = drive(&single, &trace.queries, &arrivals, &p);
        let s2 = drive(&single, &trace.queries, &arrivals, &p);
        assert_eq!(s1, s2, "{kind:?} single-pool drive not reproducible");
        let c1 = drive(&sharded, &trace.queries, &arrivals, &p);
        let c2 = drive(&sharded, &trace.queries, &arrivals, &p);
        assert_eq!(c1, c2, "{kind:?} sharded drive not reproducible");
        // Work conservation: every lookup served exactly once.
        assert_eq!(s1.stats.lookups as usize, trace.total_lookups());
        assert_eq!(c1.stats.lookups as usize, trace.total_lookups());
        assert_eq!(s1.queries(), QUERIES);
        assert_eq!(c1.queries(), QUERIES);
        // Percentiles monotone in the quantile on both backends.
        for r in [&s1, &c1] {
            let qs: Vec<f64> = [50.0, 90.0, 95.0, 99.0, 99.9, 100.0]
                .iter()
                .map(|&q| r.percentile_ns(q))
                .collect();
            assert!(qs.windows(2).all(|w| w[1] >= w[0]), "{kind:?}: {qs:?}");
        }
    }
}

#[test]
fn near_zero_load_p99_collapses_to_pure_service_time() {
    let (prepared, trace) = setup();
    let backend = prepared.sim().unwrap();
    // 10 q/s against µs-scale service times, max_wait 0: every query is
    // served alone, immediately.
    let arrivals = Arrivals::poisson(10.0, 1).take(QUERIES);
    let report = drive(&backend, &trace.queries, &arrivals, &policy(32, 0));
    let sched = prepared.scheduler();
    let mut scratch = Scratch::default();
    let solo: Vec<f64> = trace
        .queries
        .iter()
        .map(|q| sched.run_batch(std::slice::from_ref(q), &mut scratch).completion_ns)
        .collect();
    // Same rank convention as OpenLoopReport::percentile_ns by
    // construction — both call metrics::percentile.
    let solo_p99 = recross::metrics::percentile(&solo, 99.0);
    // Tolerance covers the ulps lost adding/subtracting ~1e10 ns
    // arrival timestamps around the µs-scale service times.
    assert!(
        (report.percentile_ns(99.0) - solo_p99).abs() < 1e-3,
        "open-loop p99 {} != pure-service p99 {solo_p99}",
        report.percentile_ns(99.0)
    );
    assert!(report.mean_queue_depth() < 1e-2);
}

#[test]
fn recross_mapping_holds_the_tail_lower_than_naive_under_load() {
    // The serving-layer restatement of the paper's headline: at an
    // offered load the naive mapping cannot sustain, the ReCross mapping
    // still answers with a bounded tail.
    let mut cfg = Config::paper_default();
    cfg.workload.dataset = "software".into();
    cfg.workload.history_queries = 800;
    cfg.workload.eval_queries = 64;
    let naive = Deployment::of(cfg.clone())
        .scheme(Scheme::Naive)
        .scale(SCALE)
        .build()
        .unwrap();
    let recross = Deployment::of(cfg.clone())
        .scheme(Scheme::ReCross)
        .scale(SCALE)
        .build()
        .unwrap();
    let spec = DatasetSpec::by_name("software").unwrap().scaled(SCALE);
    let trace = Generator::new(&spec, cfg.workload.seed).trace(QUERIES, 99);
    let p = policy(32, 5);
    // Rate at ~half of recross capacity, far past naive capacity.
    let cap_re = QUERIES as f64
        / (recross.engine().run_trace(&trace, p.max_batch).completion_ns / 1e9);
    let arrivals = Arrivals::poisson(0.5 * cap_re, 3).take(QUERIES);
    let rn = drive(&naive.sim().unwrap(), &trace.queries, &arrivals, &p);
    let rr = drive(&recross.sim().unwrap(), &trace.queries, &arrivals, &p);
    assert!(
        rr.percentile_ns(99.0) < rn.percentile_ns(99.0),
        "recross p99 {} !< naive p99 {}",
        rr.percentile_ns(99.0),
        rn.percentile_ns(99.0)
    );
}

#[test]
fn timed_trace_replay_reproduces_the_drive() {
    let (prepared, trace) = setup();
    let backend = prepared.sim().unwrap();
    let p = policy(16, 5);
    let timed = Arrivals::bursty(150_000.0, 21).stamp(trace.clone());
    let mut buf = Vec::new();
    timed.write_to(&mut buf).unwrap();
    let loaded = TimedTrace::read_from(&mut buf.as_slice()).unwrap();
    let ts = loaded.arrivals_ns.expect("v2 kept the stamps");
    let direct = drive(&backend, &trace.queries, &ts, &p);
    let replayed = {
        let mut replay = Arrivals::replay(ts.clone());
        let again = replay.take(trace.queries.len());
        drive(&backend, &loaded.trace.queries, &again, &p)
    };
    assert_eq!(direct, replayed, "disk round-trip changed the drive");
}
