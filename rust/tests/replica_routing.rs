//! Replica-routing exactness properties (the tentpole's safety net).
//!
//! The cross-shard replica placement + power-of-two-choices routing +
//! drift-driven epoch swaps must never change *what* the cluster
//! computes: on integer-valued f32 tables every summation order is exact
//! (integer adds are lossless well below 2^24), so the replica-routed,
//! rebalanced cluster result must be **bit-identical** to the single-pool
//! reference — before, across, and after epoch swaps. Any divergence is a
//! routing bug (lost, duplicated, or misdirected lookups), not float
//! noise.

use recross::allocation::group_frequencies;
use recross::cluster::{
    simulate_with_replicas, Cluster, PoolShared, ReplicaPlan, RouteOptions, RoutePolicy,
    ShardPlan,
};
use recross::config::Config;
use recross::coordinator::{BatchPolicy, DriftMonitor, EmbeddingStore};
use recross::graph::DeltaParams;
use recross::engine::{Engine, Scheme};
use recross::graph::CoGraph;
use recross::workload::{generate, DatasetSpec, Query, Trace};

struct Fixture {
    engine: Engine,
    history: Trace,
    eval: Trace,
    /// Same catalogue, different co-purchase structure — the drifted
    /// traffic the monitor must react to.
    drifted: Trace,
    store: EmbeddingStore,
}

/// Integer-valued fixture; `group_size` 16 so the tiny catalogue still
/// yields enough groups for the Eq. 1 budget to replicate some of them.
fn fixture(seed: u64) -> Fixture {
    let spec = DatasetSpec::by_name("software").unwrap().scaled(0.02);
    let (history, eval) = generate(&spec, 600, 200, seed);
    let (_, drifted) = generate(&spec, 600, 200, seed.wrapping_add(7_777));
    let graph = CoGraph::build(&history);
    let mut cfg = Config::paper_default();
    cfg.scheme.batch_size = 64;
    cfg.scheme.group_size = 16;
    cfg.scheme.dup_ratio = 0.25;
    let engine = Engine::prepare(Scheme::ReCross, &graph, &history, &cfg);
    let dim = cfg.hardware.embedding_dim;
    let n = engine.mapping().num_embeddings();
    // Integer-valued table in [-8, 8]: exact under any summation order.
    let table: Vec<f32> = (0..n * dim)
        .map(|i| ((i.wrapping_mul(2_654_435_761)) % 17) as f32 - 8.0)
        .collect();
    let store = EmbeddingStore::from_table(engine.mapping(), dim, cfg.hardware.xbar_rows, table);
    Fixture {
        engine,
        history,
        eval,
        drifted,
        store,
    }
}

fn spawn_routed(f: &Fixture, shards: usize, drift: Option<DriftMonitor>) -> Cluster {
    let shared = PoolShared::from_engine(&f.engine);
    let plan = ShardPlan::by_locality(f.engine.mapping(), &f.history, shards, 0.10);
    let freqs = group_frequencies(&shared.mapping, &f.history);
    let replicas = ReplicaPlan::spread(&plan, &shared.replication, &freqs);
    assert!(
        replicas.cross_shard_groups() > 0,
        "fixture produced no cross-shard replicas — the tests below would be vacuous"
    );
    let opts = RouteOptions {
        policy: RoutePolicy::PowerOfTwo,
        drift,
        dup_ratio: Some(0.25),
        ..Default::default()
    };
    Cluster::spawn_routed(shared, &f.store, plan, replicas, opts, BatchPolicy::default())
        .expect("spawn routed cluster")
}

fn assert_bit_identical(f: &Fixture, cluster: &Cluster, queries: &[Query], label: &str) {
    let handle = cluster.handle();
    let responses = handle.reduce_many(queries).unwrap();
    assert_eq!(responses.len(), queries.len());
    for (q, r) in queries.iter().zip(&responses) {
        let expect = f.store.reduce_reference(&q.items);
        assert_eq!(
            r.reduced, expect,
            "{label}: replica-routed reduction differs from single-pool reference for {:?}",
            q.items
        );
    }
    // Routing changes placement, never work: activations are conserved.
    let acts: u64 = responses.iter().map(|r| r.activations).sum();
    let reference = f.engine.count_activations(&Trace {
        num_embeddings: f.eval.num_embeddings,
        queries: queries.to_vec(),
    });
    assert_eq!(acts, reference, "{label}: activations not conserved");
}

#[test]
fn prop_routed_cluster_bit_identical_across_epoch_swaps() {
    // Property loop: independent random instances (seeded — failures
    // reproduce by seed). Each case serves through the replica-routed
    // pool, forces a drift-triggered epoch swap onto the drifted traffic,
    // and re-verifies bit-exactness after every swap.
    for case in 0..3u64 {
        let f = fixture(42 + case * 1_000);
        // Baseline far below reality + tiny warmup: the monitor must
        // trigger deterministically once warmup queries are observed.
        let drift = DriftMonitor::new(1e-3, 1.3, 0.5, 16);
        let cluster = spawn_routed(&f, 4, Some(drift));
        let handle = cluster.handle();
        assert_eq!(cluster.epoch(), 0);

        let wave1: Vec<Query> = f.eval.queries.iter().take(64).cloned().collect();
        assert_bit_identical(&f, &cluster, &wave1, "epoch 0");
        assert!(
            handle.rebalance_due(),
            "case {case}: drift monitor failed to trigger after warmup"
        );

        // Epoch swap onto the drifted distribution.
        let recent = Trace {
            num_embeddings: f.drifted.num_embeddings,
            queries: f.drifted.queries.iter().take(200).cloned().collect(),
        };
        let epoch = cluster.rebalance(&recent).unwrap();
        assert_eq!(epoch, 1, "case {case}");
        assert_eq!(cluster.epoch(), 1);

        // Serve the *drifted* traffic under the new placement: still
        // bit-identical.
        let wave2: Vec<Query> = f.drifted.queries.iter().skip(64).take(64).cloned().collect();
        assert_bit_identical(&f, &cluster, &wave2, "epoch 1");

        // A second swap keeps working (epochs are monotonic).
        let epoch = cluster.rebalance(&recent).unwrap();
        assert_eq!(epoch, 2, "case {case}");
        let wave3: Vec<Query> = f.eval.queries.iter().skip(100).take(50).cloned().collect();
        assert_bit_identical(&f, &cluster, &wave3, "epoch 2");

        // Every shard executor serves the latest epoch.
        for st in handle.shard_status().unwrap() {
            assert_eq!(st.epoch, 2, "case {case}: shard {} stale", st.shard);
        }
    }
}

#[test]
fn delta_skipped_shards_adopt_the_new_epoch() {
    // Regression: shards whose tiles a delta rebalance leaves untouched
    // used to keep reporting the older epoch in `shard_status` after the
    // routing-table swap. They now adopt the new epoch via an ack-gated
    // bump, so status rows stay uniform across the pool.
    let f = fixture(42);
    let drift = DriftMonitor::new(1e-3, 1.3, 0.5, 16);
    let cluster = spawn_routed(&f, 4, Some(drift));

    // A full swap seeds the delta baseline at epoch 1.
    let recent = Trace {
        num_embeddings: f.history.num_embeddings,
        queries: f.history.queries.iter().take(200).cloned().collect(),
    };
    assert_eq!(cluster.rebalance(&recent).unwrap(), 1);

    // Delta rebalance on the *same* window: no group drifts past the
    // thresholds, so no shard receives a tile install — exactly the case
    // that used to leave every status row at the old epoch.
    let report = cluster
        .rebalance_incremental(&recent, &DeltaParams::default())
        .unwrap();
    assert_eq!(report.epoch, 2);
    assert!(!report.full);
    assert_eq!(
        report.shards_installed, 0,
        "an identical window must skip every install"
    );
    assert_eq!(cluster.epoch(), 2);
    for st in cluster.handle().shard_status().unwrap() {
        assert_eq!(st.epoch, 2, "shard {} reports a stale epoch", st.shard);
    }

    // Skipped shards kept their tiles: serving stays bit-identical.
    let wave: Vec<Query> = f.eval.queries.iter().take(64).cloned().collect();
    assert_bit_identical(&f, &cluster, &wave, "post-delta epoch 2");
}

#[test]
fn routed_cluster_matches_reference_without_swaps() {
    let f = fixture(42);
    let cluster = spawn_routed(&f, 4, None);
    let queries: Vec<Query> = f.eval.queries.iter().take(128).cloned().collect();
    assert_bit_identical(&f, &cluster, &queries, "static placement");

    // Shard executors saw every lookup exactly once.
    let statuses = cluster.handle().shard_status().unwrap();
    let lookups: u64 = statuses.iter().map(|s| s.lookups).sum();
    let expect: u64 = queries.iter().map(|q| q.len() as u64).sum();
    assert_eq!(lookups, expect);
}

#[test]
fn replica_routing_no_worse_than_pinned_on_skewed_trace() {
    // The acceptance comparison, on the deterministic simulator: same
    // plan, same Eq. 1 copies — spreading + p2c routing must cut the
    // hottest shard's load and not hurt simulated completion.
    let f = fixture(42);
    let shared = PoolShared::from_engine(&f.engine);
    let plan = ShardPlan::by_locality(f.engine.mapping(), &f.history, 4, 0.10);
    let freqs = group_frequencies(&shared.mapping, &f.history);
    let pinned_plan = ReplicaPlan::pinned(&plan, &shared.replication);
    let spread_plan = ReplicaPlan::spread(&plan, &shared.replication, &freqs);
    let pinned =
        simulate_with_replicas(&shared, &plan, &pinned_plan, &f.eval, 64, RoutePolicy::Pinned);
    let routed = simulate_with_replicas(
        &shared,
        &plan,
        &spread_plan,
        &f.eval,
        64,
        RoutePolicy::PowerOfTwo,
    );
    assert_eq!(routed.stats.activations, pinned.stats.activations);
    assert_eq!(routed.stats.lookups, pinned.stats.lookups);
    assert!(
        routed.max_shard_load() <= pinned.max_shard_load(),
        "routing made the hot shard hotter: {} vs {}",
        routed.max_shard_load(),
        pinned.max_shard_load()
    );
    assert!(
        routed.stats.completion_ns <= pinned.stats.completion_ns * 1.05,
        "routed completion {} much worse than pinned {}",
        routed.stats.completion_ns,
        pinned.stats.completion_ns
    );
}

#[test]
fn cold_start_ids_reduce_exactly_over_known_items() {
    // Regression for the Mapping::slot_of cold-start fix, end to end: a
    // query mixing known ids with ids the catalogue has never seen must
    // not panic, and must reduce to exactly the known items' sum.
    let f = fixture(42);
    let cluster = spawn_routed(&f, 2, None);
    let handle = cluster.handle();
    let known = f.eval.queries[0].items.clone();
    let mut items = known.clone();
    items.push(5_000_000); // far outside the catalogue
    let r = handle.reduce(&items).unwrap();
    assert_eq!(r.reduced, f.store.reduce_reference(&known));
}
