//! Property-based tests over the coordinator's core invariants.
//!
//! `proptest` is not available in this offline environment, so properties
//! are checked the classical way: a seeded PRNG drives many random cases
//! per property, and failures print the seed for replay. Each `CASES`
//! iteration is an independent random instance.

use recross::allocation::{self, Replication};
use recross::config::Config;
use recross::coordinator::{EmbeddingStore, Planner};
use recross::engine::{Engine, Scheme};
use recross::graph::CoGraph;
use recross::grouping::{CorrelationMapper, FrequencyMapper, Mapper, NaiveMapper};
use recross::metrics::Summary;
use recross::obs::{MetricsRegistry, MetricsSnapshot};
use recross::sched::Scratch;
use recross::util::Rng;
use recross::workload::{Query, Trace};

const CASES: usize = 40;
const TRACE_SALT: u64 = 0x7FAC_E000;

/// Random trace over `n` embeddings.
fn random_trace(rng: &mut Rng, n: u32, queries: usize, max_len: usize) -> Trace {
    let qs = (0..queries)
        .map(|_| {
            let len = rng.range(1, max_len as u64) as usize;
            Query::new((0..len).map(|_| rng.below(n as u64) as u32).collect())
        })
        .collect();
    Trace {
        num_embeddings: n,
        queries: qs,
    }
}

#[test]
fn prop_every_mapper_is_a_partition() {
    // All three mappers must place every embedding exactly once with no
    // group over capacity (Mapping::from_groups asserts this internally —
    // the property is that it never panics on any input).
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed);
        let n = rng.range(1, 500) as u32;
        let group_size = rng.range(1, 128) as usize;
        let trace = random_trace(&mut rng, n, 50, 12);
        let graph = CoGraph::build(&trace);
        for mapper in [
            &NaiveMapper as &dyn Mapper,
            &FrequencyMapper,
            &CorrelationMapper,
        ] {
            let m = mapper.map(&graph, group_size);
            assert_eq!(m.num_embeddings(), n as usize, "seed {seed}");
            let placed: usize = m.groups.iter().map(Vec::len).sum();
            assert_eq!(placed, n as usize, "seed {seed} mapper {}", mapper.name());
        }
    }
}

#[test]
fn prop_groups_touched_bounds() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed ^ 0xA5);
        let n = rng.range(10, 400) as u32;
        let trace = random_trace(&mut rng, n, 30, 20);
        let graph = CoGraph::build(&trace);
        let m = CorrelationMapper.map(&graph, 16);
        let mut scratch = Vec::new();
        for q in &trace.queries {
            let touched = m.groups_touched(&q.items, &mut scratch);
            assert!(touched >= 1, "seed {seed}");
            assert!(touched <= q.len(), "seed {seed}: more groups than items");
            assert!(touched <= m.num_groups(), "seed {seed}");
        }
    }
}

#[test]
fn prop_eq1_monotone_and_bounded() {
    // Eq. 1: copies are >= 1, <= batch, and monotone in frequency.
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed ^ 0xE1);
        let total = rng.range(100, 1_000_000);
        let batch = rng.range(2, 1024) as usize;
        let mut prev = 0;
        for f in [1u64, 10, 100, 1_000, 10_000, 100_000] {
            let f = f.min(total);
            let c = allocation::log_scaled_copies(f, total, batch);
            assert!(c >= 1 && c as usize <= batch, "seed {seed}");
            assert!(c >= prev, "seed {seed}: not monotone");
            prev = c;
        }
    }
}

#[test]
fn prop_replication_budget_never_exceeded() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed ^ 0xB0D);
        let groups = rng.range(1, 300) as usize;
        let freqs: Vec<u64> = (0..groups).map(|_| rng.below(10_000)).collect();
        let ratio = rng.next_f64();
        let plan = allocation::plan_replication(&freqs, 256, ratio);
        assert_eq!(plan.copies.len(), groups);
        assert!(plan.copies.iter().all(|&c| c >= 1), "seed {seed}");
        let extra = plan.total_crossbars - groups;
        assert!(
            extra <= (groups as f64 * ratio) as usize,
            "seed {seed}: budget exceeded ({extra})"
        );
    }
}

#[test]
fn prop_scheduler_conservation_and_ordering() {
    // For any workload: activations & lookups are conserved; dynamic
    // switching never increases energy; duplication never increases
    // completion time.
    let cfg = Config::paper_default();
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed ^ 0x5C4ED);
        let n = rng.range(64, 600) as u32;
        let trace = random_trace(&mut rng, n, 80, 24);
        let graph = CoGraph::build(&trace);

        let on = Engine::prepare(Scheme::ReCross, &graph, &trace, &cfg);
        let off = Engine::prepare(Scheme::ReCrossNoSwitch, &graph, &trace, &cfg);
        let nodup = Engine::prepare(Scheme::ReCrossNoDup, &graph, &trace, &cfg);

        let s_on = on.run_trace(&trace, 32);
        let s_off = off.run_trace(&trace, 32);
        let s_nodup = nodup.run_trace(&trace, 32);

        // conservation
        assert_eq!(s_on.lookups as usize, trace.total_lookups(), "seed {seed}");
        assert_eq!(
            s_on.activations,
            on.count_activations(&trace),
            "seed {seed}: sim and counter disagree"
        );
        assert_eq!(
            s_on.mac_activations + s_on.read_activations,
            s_on.activations,
            "seed {seed}"
        );
        // orderings
        assert!(s_on.energy_pj <= s_off.energy_pj + 1e-6, "seed {seed}");
        assert!(
            s_on.completion_ns <= s_nodup.completion_ns + 1e-6,
            "seed {seed}: duplication made things worse"
        );
        // sanity
        assert!(s_on.completion_ns > 0.0 && s_on.energy_pj > 0.0);
    }
}

#[test]
fn prop_planner_reduction_equals_reference() {
    // For any mapping and any query: the planned masks applied to the
    // gathered tiles reproduce the master-table sum exactly.
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed ^ 0x9A7);
        let n = rng.range(16, 300) as u32;
        let dim = rng.range(2, 24) as usize;
        let rows = rng.range(4, 64) as usize;
        let group_size = rng.range(1, rows as u64) as usize;
        let tiles_per_call = rng.range(1, 6) as usize;

        let trace = random_trace(&mut rng, n, 20, 10);
        let graph = CoGraph::build(&trace);
        let mapping = CorrelationMapper.map(&graph, group_size);
        let table: Vec<f32> = (0..n as usize * dim)
            .map(|_| rng.normal() as f32)
            .collect();
        let store = EmbeddingStore::from_table(&mapping, dim, rows, table);
        let planner = Planner::new(&mapping, &store, tiles_per_call);

        let q = &trace.queries[0];
        let mut total = vec![0.0f32; dim];
        let mut tiles = Vec::new();
        for pass in planner.plan(q) {
            planner.gather_tiles(&pass, &mut tiles);
            for t in 0..pass.groups.len() {
                for r in 0..rows {
                    let w = pass.masks[t * rows + r];
                    if w != 0.0 {
                        for d in 0..dim {
                            total[d] += w * tiles[(t * rows + r) * dim + d];
                        }
                    }
                }
            }
        }
        let expect = store.reduce_reference(&q.items);
        for (a, b) in total.iter().zip(&expect) {
            assert!(
                (a - b).abs() < 1e-3,
                "seed {seed}: {a} vs {b} (n={n} dim={dim} rows={rows} gs={group_size})"
            );
        }
    }
}

#[test]
fn prop_summary_merge_matches_sequential_add() {
    // The metrics plane's per-shard collection path: any partition of a
    // stream into locally-accumulated Summaries, merged in order, must
    // match feeding the whole stream through one Summary. Counts and
    // extrema are exact; mean/variance are Welford-merged floats, so
    // they match to tight relative tolerance.
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed ^ 0x5E_55);
        let n = rng.range(1, 400) as usize;
        // Mix scales so catastrophic cancellation would show up if the
        // merge were naive (summing raw squares instead of Welford).
        let scale = 10f64.powi(rng.range(0, 6) as i32);
        let stream: Vec<f64> = (0..n).map(|_| rng.normal() * scale + scale).collect();

        let mut sequential = Summary::new();
        for &x in &stream {
            sequential.add(x);
        }

        // Random partition: each element opens a new chunk with p ~ 1/4.
        let mut merged = Summary::new();
        let mut chunk = Summary::new();
        for &x in &stream {
            if chunk.count() > 0 && rng.below(4) == 0 {
                merged.merge(&chunk);
                chunk = Summary::new();
            }
            chunk.add(x);
        }
        merged.merge(&chunk);
        // Merging an empty partition is a no-op.
        merged.merge(&Summary::new());

        assert_eq!(merged.count(), sequential.count(), "seed {seed}");
        assert_eq!(merged.min(), sequential.min(), "seed {seed}");
        assert_eq!(merged.max(), sequential.max(), "seed {seed}");
        let rel = |a: f64, b: f64| (a - b).abs() / b.abs().max(1e-12);
        assert!(
            rel(merged.mean(), sequential.mean()) < 1e-9,
            "seed {seed}: mean {} vs {}",
            merged.mean(),
            sequential.mean()
        );
        assert!(
            rel(merged.variance(), sequential.variance()) < 1e-6,
            "seed {seed}: variance {} vs {}",
            merged.variance(),
            sequential.variance()
        );
    }
}

#[test]
fn prop_snapshot_merge_identity_saturation_and_null_gauges() {
    // Export-side counterparts of the Summary property above, for
    // `MetricsSnapshot::merge`: the empty snapshot is a two-sided
    // identity (byte-identical JSON), counter and histogram-bucket
    // unions saturate near `u64::MAX` instead of wrapping, and a
    // non-finite gauge survives merge + export as JSON `null`.
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed ^ 0x0B5E);
        let r = MetricsRegistry::new();
        for _ in 0..rng.range(1, 16) {
            r.incr("c", rng.below(1_000));
            r.gauge_set("g", rng.normal());
            r.observe("s", rng.normal());
            r.record_hist("h", rng.below(64), 1 + rng.below(8));
        }
        let snap = r.snapshot("shard");
        let empty = MetricsRegistry::new().snapshot("shard");

        // Merge-of-empty identity, both sides: JSON equality is byte
        // equality (BTreeMap ordering is deterministic).
        let mut a = snap.clone();
        a.merge(&empty);
        assert_eq!(a.to_json(), snap.to_json(), "seed {seed}: right identity");
        let mut b = empty.clone();
        b.merge(&snap);
        assert_eq!(b.to_json(), snap.to_json(), "seed {seed}: left identity");
    }

    // Counter totals and bucket-count unions near u64::MAX clamp
    // instead of wrapping past zero.
    let mut near = MetricsSnapshot::default();
    near.counters.insert("c".into(), u64::MAX - 1);
    near.histograms.insert("h".into(), vec![(7, u64::MAX - 1)]);
    let mut more = MetricsSnapshot::default();
    more.counters.insert("c".into(), 5);
    more.histograms.insert("h".into(), vec![(7, 5), (9, 1)]);
    near.merge(&more);
    assert_eq!(near.counters["c"], u64::MAX);
    assert_eq!(near.histograms["h"], vec![(7, u64::MAX), (9, 1)]);

    // Non-finite gauges export as JSON null, merged or not.
    let nan = MetricsRegistry::new();
    nan.gauge_set("g", f64::NAN);
    let mut merged = MetricsRegistry::new().snapshot("shard");
    merged.merge(&nan.snapshot("shard"));
    assert!(
        merged.to_json().contains("\"g\": null"),
        "NaN gauge must export as null"
    );
}

#[test]
fn prop_trace_roundtrip_any_content() {
    for seed in 0..CASES as u64 {
        let mut rng = Rng::new(seed ^ TRACE_SALT);
        let n = rng.range(1, 1000) as u32;
        let queries = rng.range(0, 40) as usize;
        let t = random_trace(&mut rng, n, queries, 16);
        let mut buf = Vec::new();
        t.write_to(&mut buf).unwrap();
        let back = Trace::read_from(&mut buf.as_slice()).unwrap();
        assert_eq!(t, back, "seed {seed}");
    }
}

#[test]
fn prop_identity_replication_matches_no_dup_schedule() {
    // Scheduling with an identity replication must equal the NoDup
    // engine's behaviour exactly (stats equality, not just ordering),
    // and scheduling must be deterministic.
    let cfg = Config::paper_default();
    for seed in 0..10u64 {
        let mut rng = Rng::new(seed ^ 0x1D);
        let trace = random_trace(&mut rng, 256, 40, 16);
        let graph = CoGraph::build(&trace);
        let e = Engine::prepare(Scheme::ReCrossNoDup, &graph, &trace, &cfg);
        let ident = Replication::identity(e.mapping().num_groups(), cfg.scheme.batch_size);
        assert_eq!(e.replication().copies, ident.copies, "seed {seed}");
        let mut s1 = Scratch::default();
        let mut s2 = Scratch::default();
        let a = e.run_batch(&trace.queries[..32], &mut s1);
        let b = e.run_batch(&trace.queries[..32], &mut s2);
        assert_eq!(a, b, "seed {seed}: scheduling must be deterministic");
    }
}
