//! End-to-end contracts of the observability plane (`recross::obs`):
//!
//! * recording never perturbs the drive — reports are bit-identical
//!   with a handle attached, disabled or enabled;
//! * an enabled drive covers the metric catalogue, and the recorded
//!   counters reconcile exactly with the report's own accounting;
//! * a disabled handle records nothing;
//! * `Backend::metrics` merges the `status.*` family with the obs
//!   harvest into one schema-versioned snapshot;
//! * the flight recorder emits Chrome trace-event JSON, and
//!   `sample_rate: 0` keeps metrics while dropping spans.

use recross::allocation::Replication;
use recross::cluster::{PoolShared, ShardPlan};
use recross::config::{HardwareConfig, ObsConfig, SloConfig, WatchConfig};
use recross::coordinator::BatchPolicy;
use recross::deploy::{Backend, SimBackend};
use recross::grouping::Mapping;
use recross::loadgen::{drive, Arrivals, ReportWindow};
use recross::obs::{names, MetricsSnapshot, Obs, Objective, SloTracker, TimeSeries, Watcher};
use recross::util::{Clock, SimClock};
use recross::workload::Query;
use recross::xbar::{CircuitParams, CrossbarModel};
use std::sync::Arc;
use std::time::Duration;

const GROUPS: usize = 4;
const GROUP_SIZE: usize = 4;

fn shared() -> PoolShared {
    let groups: Vec<Vec<u32>> = (0..GROUPS)
        .map(|g| ((g * GROUP_SIZE) as u32..((g + 1) * GROUP_SIZE) as u32).collect())
        .collect();
    PoolShared {
        mapping: Mapping::from_groups(groups, GROUP_SIZE, GROUPS * GROUP_SIZE),
        replication: Replication::identity(GROUPS, 8),
        model: CrossbarModel::new(&HardwareConfig::default(), &CircuitParams::default()),
        dynamic_switch: true,
    }
}

/// Alternating group ownership over two shards, so the pooling queries
/// below always fan out to both (the merge path is exercised).
fn plan2() -> ShardPlan {
    ShardPlan::from_assignment(vec![0, 1, 0, 1], 2)
}

/// Every query touches groups 0, 1, 2 — shards 0 and 1 under [`plan2`].
fn queries(n: usize) -> Vec<Query> {
    (0..n)
        .map(|i| {
            let j = (i % GROUP_SIZE) as u32;
            Query::new(vec![j, GROUP_SIZE as u32 + j, 2 * GROUP_SIZE as u32 + j])
        })
        .collect()
}

fn policy() -> BatchPolicy {
    BatchPolicy {
        max_batch: 4,
        max_wait: Duration::from_micros(5),
    }
}

fn enabled_obs(sample_rate: f64) -> Arc<Obs> {
    Obs::from_config(&ObsConfig {
        enabled: true,
        sample_rate,
        ring_capacity: 1024,
    })
}

#[test]
fn recording_does_not_perturb_the_drive() {
    let sh = shared();
    let qs = queries(200);
    let arrivals = Arrivals::poisson(2_000_000.0, 7).take(200);
    let p = policy();
    for sharded in [false, true] {
        let make = || {
            let b = SimBackend::single(&sh);
            if sharded {
                b.into_sharded(plan2())
            } else {
                b
            }
        };
        let base = drive(&make(), &qs, &arrivals, &p);
        let with_disabled = drive(&make().with_obs(Obs::disabled()), &qs, &arrivals, &p);
        let with_enabled = drive(&make().with_obs(enabled_obs(1.0)), &qs, &arrivals, &p);
        assert_eq!(base, with_disabled, "disabled handle perturbed the drive");
        assert_eq!(base, with_enabled, "enabled handle perturbed the drive");
    }
}

#[test]
fn enabled_drive_covers_the_metric_catalogue() {
    let sh = shared();
    let obs = enabled_obs(1.0);
    let backend = SimBackend::single(&sh)
        .into_sharded(plan2())
        .with_obs(Arc::clone(&obs));
    let qs = queries(100);
    let arrivals = Arrivals::poisson(2_000_000.0, 3).take(100);
    let report = drive(&backend, &qs, &arrivals, &policy());
    let snap = obs.snapshot("sim");

    // Batcher seam: one queue-depth observation, one batch-size bucket,
    // and one close-reason increment per batch close.
    assert_eq!(
        snap.summaries[names::BATCHER_QUEUE_DEPTH].count(),
        report.batches()
    );
    let sizes: u64 = snap.histograms[names::BATCHER_BATCH_SIZE]
        .iter()
        .map(|&(_, c)| c)
        .sum();
    assert_eq!(sizes, report.batches());
    assert_eq!(
        snap.counter(names::BATCHER_CLOSE_SIZE) + snap.counter(names::BATCHER_CLOSE_DEADLINE),
        report.batches()
    );
    // One formation-wait observation per served sub-query.
    assert_eq!(
        snap.summaries[names::BATCHER_WAIT_NS].count(),
        report.stats.queries
    );

    // Scheduler / crossbar / ADC / energy: the harvest reconciles with
    // the report's own ExecStats accounting, counter for counter.
    assert_eq!(snap.counter(names::SCHED_BATCHES), report.batches());
    assert_eq!(snap.counter(names::SCHED_QUERIES), report.stats.queries);
    assert_eq!(snap.counter(names::SCHED_LOOKUPS), report.stats.lookups);
    assert_eq!(
        snap.counter(names::SCHED_PATH_FLAT) + snap.counter(names::SCHED_PATH_TREE),
        2 * report.batches(),
        "one busy-table + one bus-table path tag per batch"
    );
    assert!(snap.counter(names::SCHED_COMPARISONS) > 0);
    assert_eq!(snap.counter(names::XBAR_ACTIVATIONS), report.stats.activations);
    assert_eq!(
        snap.counter(names::XBAR_SINGLE_ROW),
        report.stats.single_row_activations
    );
    assert_eq!(snap.counter(names::ADC_MAC), report.stats.mac_activations);
    assert_eq!(snap.counter(names::ADC_READ), report.stats.read_activations);
    // The gauge holds crossbar service energy only; the report also
    // charges the front-end merge adds.
    let pj = snap.gauge(names::ENERGY_TOTAL_PJ);
    assert!(pj > 0.0 && pj <= report.stats.energy_pj + 1e-9);

    // Scatter-gather seam (every query here fans out to both shards).
    assert_eq!(snap.counter(names::CLUSTER_ROUTE_PINNED), qs.len() as u64);
    assert_eq!(
        snap.counter(names::CLUSTER_SUBQUERIES),
        report.stats.queries
    );
    let fanned: u64 = snap.histograms[names::CLUSTER_FANOUT]
        .iter()
        .map(|&(_, c)| c)
        .sum();
    assert_eq!(fanned, qs.len() as u64);
    assert_eq!(snap.histograms[names::CLUSTER_FANOUT], vec![(2, qs.len() as u64)]);
}

#[test]
fn disabled_handle_records_nothing_through_the_drive() {
    let sh = shared();
    let obs = Obs::disabled();
    let backend = SimBackend::single(&sh).with_obs(Arc::clone(&obs));
    let qs = queries(50);
    let arrivals = Arrivals::poisson(1_000_000.0, 5).take(50);
    drive(&backend, &qs, &arrivals, &policy());
    let snap = obs.snapshot("off");
    assert!(snap.counters.is_empty());
    assert!(snap.gauges.is_empty());
    assert!(snap.summaries.is_empty());
    assert!(snap.histograms.is_empty());
    assert!(obs.recorder().is_empty());
}

#[test]
fn backend_metrics_merges_status_and_obs_families() {
    let sh = shared();
    // No handle: the default Backend::metrics still emits the status.*
    // family (all zeros on the stateless simulator) under the schema.
    let bare = SimBackend::single(&sh);
    let snap = bare.metrics().expect("metrics");
    assert_eq!(snap.source, "sim");
    assert_eq!(snap.counter("status.queries"), 0);
    assert_eq!(snap.counter("status.batches"), 0);
    assert_eq!(snap.gauge("status.energy_pj"), 0.0);
    assert_eq!(snap.counter(names::SCHED_BATCHES), 0);

    // Enabled handle: one snapshot carries both families.
    let obs = enabled_obs(1.0);
    let backend = SimBackend::single(&sh).with_obs(Arc::clone(&obs));
    let qs = queries(60);
    let arrivals = Arrivals::poisson(1_000_000.0, 9).take(60);
    let report = drive(&backend, &qs, &arrivals, &policy());
    let snap = backend.metrics().expect("metrics");
    assert!(snap.counters.contains_key("status.queries"));
    assert_eq!(snap.counter(names::SCHED_BATCHES), report.batches());

    let js = snap.to_json();
    assert!(js.contains(&format!("\"schema\": \"{}\"", MetricsSnapshot::SCHEMA)));
    assert!(js.contains(&format!("\"version\": {}", MetricsSnapshot::VERSION)));
    assert!(js.contains("\"sched.batches\""));
}

#[test]
fn flight_recorder_emits_chrome_trace_spans() {
    let sh = shared();
    let obs = enabled_obs(1.0);
    let backend = SimBackend::single(&sh)
        .into_sharded(plan2())
        .with_obs(Arc::clone(&obs));
    let qs = queries(40);
    let arrivals = Arrivals::poisson(2_000_000.0, 1).take(40);
    drive(&backend, &qs, &arrivals, &policy());

    assert!(!obs.recorder().is_empty());
    assert!(obs.recorder().recorded() > 0);
    let js = obs.recorder().trace_json();
    assert!(js.contains("\"traceEvents\""));
    assert!(js.contains("\"ph\": \"X\""));
    // The per-query lifecycle on this fixture: queue wait, crossbar
    // service, and (fanout 2 everywhere) the scatter-gather merge.
    assert!(js.contains("\"name\": \"enqueue\""));
    assert!(js.contains("\"name\": \"execute\""));
    assert!(js.contains("\"name\": \"merge\""));
    // Spans land on their executor's track.
    assert!(js.contains("\"tid\": 1"));
}

#[test]
fn zero_sample_rate_keeps_metrics_and_drops_spans() {
    let sh = shared();
    let obs = enabled_obs(0.0);
    let backend = SimBackend::single(&sh).with_obs(Arc::clone(&obs));
    let qs = queries(50);
    let arrivals = Arrivals::poisson(1_000_000.0, 2).take(50);
    let report = drive(&backend, &qs, &arrivals, &policy());
    let snap = obs.snapshot("sim");
    assert_eq!(snap.counter(names::SCHED_BATCHES), report.batches());
    assert!(obs.recorder().is_empty(), "no query may be sampled at rate 0");
}

#[test]
fn ticking_watcher_never_perturbs_the_drive() {
    // Observation-never-perturbs, extended to the signal plane: the
    // drive's report is bit-identical with the watcher off, with a
    // ticking time-series, and with ticking + SLO evaluation — the
    // watcher only ever *reads* snapshots between drives.
    let sh = shared();
    let qs = queries(200);
    let arrivals = Arrivals::poisson(2_000_000.0, 7).take(200);
    let p = policy();
    for sharded in [false, true] {
        let make = || {
            let b = SimBackend::single(&sh);
            let b = if sharded { b.into_sharded(plan2()) } else { b };
            b.with_obs(enabled_obs(1.0))
        };

        // Watcher off.
        let off = drive(&make(), &qs, &arrivals, &p);

        // Ticking: three drive rounds, a time-series diff after each.
        let backend = make();
        let clock = SimClock::new();
        let mut series = TimeSeries::new(64);
        let mut ticking = None;
        for _ in 0..3 {
            ticking = Some(drive(&backend, &qs, &arrivals, &p));
            clock.advance(10_000_000);
            series.tick(clock.now_ns(), &backend.metrics().expect("snapshot"));
        }
        assert_eq!(off, ticking.unwrap(), "ticking time-series perturbed the drive");
        assert_eq!(series.ticks(), 3);

        // Ticking + SLO evaluation over the default objectives.
        let backend = make();
        let clock = SimClock::new();
        let mut watcher = Watcher::from_config(&WatchConfig::default(), &SloConfig::default());
        let mut evaluated = None;
        for _ in 0..3 {
            evaluated = Some(drive(&backend, &qs, &arrivals, &p));
            clock.advance(10_000_000);
            let _ = watcher.tick(clock.now_ns(), &backend.metrics().expect("snapshot"));
        }
        assert_eq!(off, evaluated.unwrap(), "SLO evaluation perturbed the drive");
    }
}

#[test]
fn overload_phase_fires_the_fast_burn_alert_deterministically() {
    use recross::obs::slo::{Cmp, SloSignal};

    // Hand-stamped arrival plan: a steady phase (batch-sized groups of
    // 4, one group per ms, so every batch closes on size and sojourn is
    // pure service time), then an injected overload at 250 ms — 200
    // queries all offered in one instant, so the queue drains serially
    // and that window's p99 sojourn carries ~50 batch services of wait.
    const WINDOW_NS: u64 = 10_000_000;
    let sh = shared();
    let p = policy();
    let qs = queries(400);
    let mut arrivals: Vec<u64> = (0..200u64).map(|i| (i / 4) * 1_000_000).collect();
    arrivals.resize(400, 250_000_000);
    let backend = SimBackend::single(&sh);
    let report = drive(&backend, &qs, &arrivals, &p);

    let windows = report.windows(WINDOW_NS);
    assert_eq!(windows.first().expect("windows").index, 0);
    assert_eq!(windows.last().expect("windows").index, 25);
    let steady_max = windows[..5]
        .iter()
        .map(|w| w.percentile_ns(99.0))
        .fold(0.0f64, f64::max);
    let burst = windows.last().expect("windows");
    assert_eq!(burst.queries(), 200);
    let burst_p99 = burst.percentile_ns(99.0);
    assert!(
        burst_p99 > steady_max,
        "overload must degrade windowed p99: {burst_p99} vs {steady_max}"
    );
    let threshold = (steady_max + burst_p99) / 2.0;

    let objective = || {
        Objective::new(
            "sojourn-p99",
            SloSignal::Gauge {
                metric: names::LOADGEN_SOJOURN_P99_NS.to_string(),
            },
            Cmp::Below,
            threshold,
        )
        .with_burn_rules(1, 4, 0.5)
    };
    // One tick per report window, the gauge stamped from the window's
    // own percentile — exactly the feeding the watch loop does live.
    let run = |windows: &[ReportWindow]| {
        let mut watcher = Watcher::new(64, SloTracker::new().with_objective(objective()));
        let clock = SimClock::new();
        let mut stream = String::new();
        for w in windows {
            clock.advance(WINDOW_NS);
            let mut snap = MetricsSnapshot::default();
            snap.gauges
                .insert(names::LOADGEN_SOJOURN_P99_NS.to_string(), w.percentile_ns(99.0));
            let (_, alerts) = watcher.tick(clock.now_ns(), &snap);
            for a in &alerts {
                stream.push_str(&a.to_json_line());
                stream.push('\n');
            }
        }
        stream
    };

    let first = run(&windows);
    let second = run(&windows);
    assert_eq!(first, second, "alert stream must be byte-identical across runs");
    assert!(
        first.contains("\"severity\": \"page\"") && first.contains("\"state\": \"firing\""),
        "the overload window must trip the fast-burn page:\n{first}"
    );

    // A steady-state run at the same seed fires nothing.
    let steady_only = run(&windows[..5]);
    assert!(steady_only.is_empty(), "steady state must stay silent: {steady_only}");
}

#[test]
fn backend_alerts_default_to_empty() {
    // Backends are passive metric sources: the trait-level default for
    // `Backend::alerts` surfaces no events, SLO evaluation lives in the
    // external watcher.
    let sh = shared();
    let bare = SimBackend::single(&sh);
    assert!(bare.alerts().is_empty());
    let observed = SimBackend::single(&sh).with_obs(enabled_obs(1.0));
    assert!(observed.alerts().is_empty());
}
