//! Tiered-store integration properties (PR 10):
//!
//! 1. **Bit-identity**: a [`TieredStore`]'s reductions are *bit-identical*
//!    to the flat [`EmbeddingStore`]'s reference reduction for every hot-set
//!    size — zero, one, half, everything-fits — and every DRAM capacity.
//!    Placement prices the walk; it must never change what the walk
//!    computes, and on the real (non-integer) random table bit-equality is
//!    only possible if the tiered walk visits the same rows in the same
//!    order with the same kernel.
//! 2. **Hot set = Algorithm 1 prefix**: the planned hot tier is exactly
//!    the top-`hot_capacity` prefix of the global frequency order from the
//!    offline phase's group frequencies, ties broken by ascending group id.
//! 3. **Cold-start visibility** (regression): a flood of ids the offline
//!    phase never saw routes to the overflow group, lands in the drift
//!    ring, and must eventually *promote* the overflow group out of the
//!    cold tier — before PR 10 that traffic was invisible to admission.

use recross::allocation::group_frequencies;
use recross::config::Config;
use recross::deploy::{Backend, Deployment, Prepared};
use recross::engine::Scheme;
use recross::sched::Scratch;
use recross::store::{Tier, TierCostModel, TierPolicy, TieredStore};
use recross::workload::Query;

const SCALE: f64 = 0.02;

fn cfg_small() -> Config {
    let mut cfg = Config::paper_default();
    cfg.workload.dataset = "software".into();
    cfg.workload.history_queries = 500;
    cfg.workload.eval_queries = 96;
    cfg.scheme.batch_size = 32;
    cfg
}

fn build() -> Prepared {
    Deployment::of(cfg_small())
        .scheme(Scheme::ReCross)
        .scale(SCALE)
        .build()
        .unwrap()
}

#[test]
fn reductions_are_bit_identical_to_flat_at_every_capacity() {
    let prepared = build();
    let mapping = prepared.engine().mapping();
    let store = prepared.store();
    let freqs = group_frequencies(mapping, prepared.history());
    let groups = mapping.num_groups();
    let cost = TierCostModel::new(120.0, 2_500.0);
    // Hot sizes: nothing resident, one tile, half, everything fits (and
    // over-provisioned); DRAM: unbounded and a 1-tile squeeze that forces
    // evictions to fall through to the cold file.
    for hot in [0, 1, groups / 2, groups, groups + 7] {
        for dram in [0, 1] {
            let tiered = TieredStore::build(store, &freqs, TierPolicy::new(hot, dram, 2), cost);
            for q in prepared.eval().queries.iter().take(32) {
                let got = tiered.reduce(mapping, &q.items);
                let want = store.reduce_reference(&q.items);
                // Bitwise, not approximate: compare the raw f32 words.
                let got_bits: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
                let want_bits: Vec<u32> = want.iter().map(|v| v.to_bits()).collect();
                assert_eq!(
                    got_bits, want_bits,
                    "hot={hot} dram={dram}: tiered reduction diverged from flat"
                );
            }
            // Cold-start ids beyond the catalogue contribute zero in both.
            let ghost = Query::new(vec![mapping.num_embeddings() as u32 + 1]);
            assert_eq!(
                tiered.reduce(mapping, &ghost.items),
                store.reduce_reference(&ghost.items),
                "hot={hot} dram={dram}: ghost-id handling diverged"
            );
        }
    }
}

#[test]
fn hot_set_is_the_top_frequency_prefix_of_the_global_order() {
    let prepared = build();
    let mapping = prepared.engine().mapping();
    let freqs = group_frequencies(mapping, prepared.history());
    let groups = mapping.num_groups();
    let order = TierPolicy::frequency_order(&freqs);
    // The order itself is (frequency desc, group id asc) — ties must fall
    // to the smaller id for determinism.
    for w in order.windows(2) {
        let (a, b) = (w[0], w[1]);
        assert!(
            freqs[a as usize] > freqs[b as usize]
                || (freqs[a as usize] == freqs[b as usize] && a < b),
            "frequency order violated at ({a}, {b})"
        );
    }
    for hot in [0, 1, 3, groups / 2, groups, groups + 9] {
        let tiered = TieredStore::build(
            prepared.store(),
            &freqs,
            TierPolicy::new(hot, 0, 2),
            TierCostModel::default(),
        );
        let mut expect: Vec<u32> = order.iter().copied().take(hot).collect();
        expect.sort_unstable();
        assert_eq!(
            tiered.hot_groups(),
            expect,
            "hot={hot}: hot set is not the top-frequency prefix"
        );
        assert_eq!(tiered.occupancy().0, hot.min(groups));
    }
}

#[test]
fn cold_start_flood_promotes_the_overflow_group() {
    let mut cfg = cfg_small();
    // One hot tile, fast replans, single-hit admission: the smallest
    // configuration where a sustained flood must flip the placement.
    cfg.store.hot_tiles = 1;
    cfg.store.replan_batches = 2;
    cfg.store.promote_hits = 1;
    let prepared = Deployment::of(cfg)
        .scheme(Scheme::ReCross)
        .scale(SCALE)
        .build()
        .unwrap();
    let mapping = prepared.engine().mapping();
    let overflow = mapping.overflow_group();
    let backend = prepared.sim_tiered().unwrap();
    assert_ne!(
        backend.tier_of(overflow),
        Tier::Hot,
        "fixture precondition: the overflow group must start outside the hot tier"
    );

    // A flood of ids the offline phase never saw: every lookup routes to
    // the overflow group's crossbar.
    let base = mapping.num_embeddings() as u32;
    let flood: Vec<Query> = (0..8u32)
        .map(|i| Query::new(vec![base + 2 * i, base + 2 * i + 1]))
        .collect();
    let mut scratch = Scratch::default();
    let mut finish = Vec::new();
    for _ in 0..6 {
        finish.clear();
        backend.run_batch_timed(0, &flood, &mut scratch, &mut finish);
        assert_eq!(finish.len(), flood.len());
    }
    assert_eq!(
        backend.tier_of(overflow),
        Tier::Hot,
        "a sustained cold-start flood never promoted the overflow group"
    );
    let (promotions, _) = backend.moves();
    assert!(promotions >= 1, "no promotions recorded during the flood");
    // The flood was priced: misses were charged before the promotion.
    assert!(backend.access().total() > 0);
    assert!(backend.access().miss_ns > 0.0);
}
