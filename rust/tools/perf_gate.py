#!/usr/bin/env python3
"""Perf-regression gate over the committed bench baseline.

Compares the freshly produced ``BENCH_offline.json`` / ``BENCH_sched.json``
(at the repository root) against ``rust/bench_baseline.json`` and fails if
any tracked ns-scale metric regressed by more than the tolerance band
(default 15%). Lower is better for every tracked metric, so only slowdowns
fail; speedups update silently until the baseline is re-blessed.

Usage:
    python3 rust/tools/perf_gate.py --check            # CI gate (default)
    python3 rust/tools/perf_gate.py --bless            # rewrite the baseline
    python3 rust/tools/perf_gate.py --check --tolerance 0.25

The baseline records the bench ``mode`` (smoke/full) it was blessed from;
a mode mismatch, a missing bench file, or an unblessed/empty baseline all
*pass with a notice* — the gate only ever compares like with like, and the
first run on a real toolchain blesses the starting point.
"""

import argparse
import json
import os
import sys

REPO_ROOT = os.path.normpath(os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", ".."))
BASELINE_PATH = os.path.join(REPO_ROOT, "rust", "bench_baseline.json")

# name -> (bench file, extractor of {metric_key: ns_value}); every tracked
# metric is "lower is better".
def _offline_metrics(doc):
    out = {}
    for c in doc.get("configs", []):
        name = c["name"]
        out[f"offline/{name}/full_ns"] = c["full"]["ns_per_rebuild"]
        out[f"offline/{name}/inc_ns"] = c["incremental"]["ns_per_refresh"]
        if "full_parallel" in c:
            out[f"offline/{name}/full_par_ns"] = c["full_parallel"]["ns_per_rebuild"]
        if "incremental_parallel" in c:
            out[f"offline/{name}/inc_par_ns"] = c["incremental_parallel"]["ns_per_refresh"]
    return out


def _sched_metrics(doc):
    out = {}
    for c in doc.get("configs", []):
        name = c["name"]
        out[f"sched/{name}/opt_ns"] = c["optimized"]["ns_per_batch"]
    for r in doc.get("reduce", []):
        out[f"reduce/{r['name']}/simd_ns"] = r["simd"]["ns_per_reduce"]
    return out


def _tier_metrics(doc):
    out = {}
    for p in doc.get("points", []):
        out[f"tier/{p['label']}/p99_ns"] = p["p99_ns"]
        out[f"tier/{p['label']}/p50_ns"] = p["p50_ns"]
    return out


BENCHES = {
    "offline": ("BENCH_offline.json", _offline_metrics),
    "sched": ("BENCH_sched.json", _sched_metrics),
    "tier": ("BENCH_tier.json", _tier_metrics),
}


def load_fresh():
    """Fresh bench results: {bench: (mode, {metric: ns})}; missing files skip."""
    fresh = {}
    for bench, (fname, extract) in BENCHES.items():
        path = os.path.join(REPO_ROOT, fname)
        if not os.path.exists(path):
            print(f"perf_gate: {fname} not found - skipping {bench} (notice)")
            continue
        with open(path) as f:
            doc = json.load(f)
        fresh[bench] = (doc.get("mode", "unknown"), extract(doc))
    return fresh


def cmd_bless(fresh):
    entries = {}
    for bench, (mode, metrics) in fresh.items():
        entries[bench] = {"mode": mode, "metrics": metrics}
    doc = {
        "schema": "recross.bench_baseline",
        "version": 1,
        "blessed": bool(entries),
        "entries": entries,
    }
    with open(BASELINE_PATH, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    n = sum(len(e["metrics"]) for e in entries.values())
    print(f"perf_gate: blessed {n} metrics from {len(entries)} bench(es) -> {BASELINE_PATH}")
    return 0


def cmd_check(fresh, tolerance):
    if not os.path.exists(BASELINE_PATH):
        print("perf_gate: no baseline committed - passing with notice (run --bless)")
        return 0
    with open(BASELINE_PATH) as f:
        base = json.load(f)
    if base.get("schema") != "recross.bench_baseline":
        print("perf_gate: baseline schema mismatch - passing with notice")
        return 0
    if not base.get("blessed") or not base.get("entries"):
        print("perf_gate: baseline not blessed yet - passing with notice (run --bless)")
        return 0

    failures = []
    compared = 0
    for bench, entry in base["entries"].items():
        if bench not in fresh:
            print(f"perf_gate: no fresh results for {bench} - skipping (notice)")
            continue
        mode, metrics = fresh[bench]
        if entry.get("mode") != mode:
            print(
                f"perf_gate: {bench} mode mismatch (baseline {entry.get('mode')!r} "
                f"vs fresh {mode!r}) - skipping (notice)"
            )
            continue
        for key, base_ns in entry.get("metrics", {}).items():
            if key not in metrics or base_ns <= 0:
                continue
            fresh_ns = metrics[key]
            compared += 1
            ratio = fresh_ns / base_ns
            marker = "FAIL" if ratio > 1.0 + tolerance else "ok"
            print(f"  {marker:>4}  {key:<40} {base_ns:>14.1f} -> {fresh_ns:>14.1f}  ({ratio:.3f}x)")
            if ratio > 1.0 + tolerance:
                failures.append((key, base_ns, fresh_ns, ratio))

    if failures:
        print(f"\nperf_gate: {len(failures)} metric(s) regressed past {tolerance:.0%}:")
        for key, base_ns, fresh_ns, ratio in failures:
            print(f"  {key}: {base_ns:.1f} ns -> {fresh_ns:.1f} ns ({ratio:.3f}x)")
        return 1
    print(f"perf_gate: {compared} metric(s) within the {tolerance:.0%} band")
    return 0


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--check", action="store_true", help="compare fresh results (default)")
    ap.add_argument("--bless", action="store_true", help="rewrite the baseline from fresh results")
    ap.add_argument("--tolerance", type=float, default=0.15, help="allowed slowdown fraction")
    args = ap.parse_args()
    if args.bless and args.check:
        ap.error("--bless and --check are mutually exclusive")
    fresh = load_fresh()
    if args.bless:
        return cmd_bless(fresh)
    return cmd_check(fresh, args.tolerance)


if __name__ == "__main__":
    sys.exit(main())
