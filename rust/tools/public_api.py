#!/usr/bin/env python3
"""Public-API snapshot for the `recross` crate.

A `cargo public-api`-style text dump without the external tool: walks
`rust/src`, extracts every `pub` item signature (functions, structs,
enums, traits, type aliases, consts, modules, re-exports), and writes
them one-per-line, sorted, to `rust/api.txt`.

The dump is intentionally grep-level — it tracks *names and signatures*,
not full semantics — which is exactly enough for CI to force future PRs
to acknowledge API breaks by re-running `--bless` and committing the
diff.

Usage:
    python3 rust/tools/public_api.py --bless   # regenerate rust/api.txt
    python3 rust/tools/public_api.py --check   # diff against rust/api.txt
"""

import difflib
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent  # rust/
SRC = ROOT / "src"
SNAPSHOT = ROOT / "api.txt"

# Items that open the public surface. `pub(crate)`/`pub(super)` are
# crate-internal and excluded on purpose.
ITEM = re.compile(
    r"^\s*pub\s+(?:async\s+)?(?:unsafe\s+)?"
    r"(fn|struct|enum|trait|mod|use|type|const|static)\b"
)
PUB_RESTRICTED = re.compile(r"^\s*pub\s*\(")


def strip_strings_and_comments(text: str) -> str:
    """Blank out string/char literals and comments, preserving newlines.

    Brace-depth tracking (used to skip `#[cfg(test)]` modules) must not
    count braces inside `"missing }"`-style literals or comments, or the
    skipper desynchronizes and silently drops real public items from
    the snapshot.
    """
    out = []
    i, n = 0, len(text)
    while i < n:
        c = text[i]
        if text.startswith("//", i):
            j = text.find("\n", i)
            i = n if j < 0 else j
            continue
        if text.startswith("/*", i):
            depth = 1
            i += 2
            while i < n and depth:
                if text.startswith("/*", i):
                    depth, i = depth + 1, i + 2
                elif text.startswith("*/", i):
                    depth, i = depth - 1, i + 2
                else:
                    if text[i] == "\n":
                        out.append("\n")
                    i += 1
            continue
        if c == '"':
            # String literal (incl. the contents of raw strings minus
            # their hash guards — good enough: we only need braces and
            # newlines to survive accurately).
            i += 1
            while i < n:
                if text[i] == "\\":
                    i += 2
                    continue
                if text[i] == "\n":
                    out.append("\n")
                if text[i] == '"':
                    i += 1
                    break
                i += 1
            continue
        if c == "'":
            m = re.match(r"'(\\.|[^'\\])'", text[i:])
            if m:
                i += m.end()
                continue
            out.append(c)
            i += 1
            continue
        out.append(c)
        i += 1
    return "".join(out)


def signature_lines(text: str):
    """Yield normalized public item signatures from one source file."""
    lines = strip_strings_and_comments(text).splitlines()
    in_tests = False
    depth_at_tests = 0
    depth = 0
    i = 0
    while i < len(lines):
        raw = lines[i]
        stripped = raw.strip()
        # Skip everything inside #[cfg(test)] mod ... { } blocks.
        if not in_tests and stripped.startswith("#[cfg(test)]"):
            in_tests = True
            depth_at_tests = depth
        depth += raw.count("{") - raw.count("}")
        if in_tests and depth <= depth_at_tests and "{" in raw:
            # The test module opened and closed on one line (unlikely).
            in_tests = False
        if in_tests:
            if depth <= depth_at_tests and "}" in raw:
                in_tests = False
            i += 1
            continue
        if ITEM.match(raw) and not PUB_RESTRICTED.match(raw):
            # Join continuation lines until the signature closes with
            # `{`, `;`, or balanced parens at a line end.
            sig = stripped
            j = i
            is_use = re.match(r"^\s*pub\s+use\b", raw) is not None
            end = r";\s*$" if is_use else r"[{;]\s*$"
            while not re.search(end, sig) and j + 1 < len(lines) and j - i < 12:
                j += 1
                sig += " " + lines[j].strip()
            if not is_use:
                sig = re.sub(r"\s*\{.*$", "", sig)  # drop bodies
            sig = re.sub(r";\s*$", "", sig)
            sig = re.sub(r"\s+", " ", sig).strip()
            yield sig
        i += 1


def collect():
    out = []
    for path in sorted(SRC.rglob("*.rs")):
        module = str(path.relative_to(SRC)).removesuffix(".rs")
        module = module.removesuffix("/mod") or "lib"
        module = module.replace("/", "::")
        if module == "lib":
            module = "recross"
        else:
            module = f"recross::{module}"
        for sig in signature_lines(path.read_text(encoding="utf-8")):
            out.append(f"{module} :: {sig}")
    return sorted(set(out))


def main():
    mode = sys.argv[1] if len(sys.argv) > 1 else "--check"
    current = "\n".join(collect()) + "\n"
    if mode == "--bless":
        SNAPSHOT.write_text(current, encoding="utf-8")
        print(f"wrote {SNAPSHOT} ({current.count(chr(10))} items)")
        return 0
    if mode != "--check":
        print(__doc__)
        return 2
    recorded = SNAPSHOT.read_text(encoding="utf-8") if SNAPSHOT.exists() else ""
    if recorded == current:
        print(f"public API snapshot OK ({current.count(chr(10))} items)")
        return 0
    print("public API changed — review the diff and re-bless if intended:")
    print("    python3 rust/tools/public_api.py --bless\n")
    for line in difflib.unified_diff(
        recorded.splitlines(), current.splitlines(),
        fromfile="rust/api.txt (recorded)", tofile="current source", lineterm="",
    ):
        print(line)
    return 1


if __name__ == "__main__":
    sys.exit(main())
